"""Int8 error-feedback gradient compression (cross-pod wire format).

Per-leaf symmetric int8 quantization with an error-feedback residual: the
quantization error of step t is added back into the gradient at step t+1,
so the compressed optimizer sees an unbiased long-run gradient (EF-SGD).
The invariant ``dequantize(quantize(x + e)) + e' == x + e`` holds exactly
by construction — e' *is* the representation error.

Everything here is jit-safe (used inside the donated train step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _scale_for(x):
    """Max-abs scale; matrices (ndim ≥ 2) get one scale per leading-axis
    row — per-tensor scales are far too coarse for gradient trees whose
    leaves mix dense and near-empty rows (e.g. embeddings)."""
    xf = x.astype(jnp.float32)
    if xf.ndim >= 2:
        amax = jnp.max(jnp.abs(xf), axis=tuple(range(1, xf.ndim)),
                       keepdims=True)
    else:
        amax = jnp.max(jnp.abs(xf))
    return jnp.where(amax > 0, amax / 127.0, jnp.float32(1.0))


def _encode(x, scale):
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8)


def quantize_int8(x):
    """Symmetric max-abs int8 quantization: returns (codes int8, scale)."""
    scale = _scale_for(x)
    return _encode(x, scale), scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_quantize(x, err):
    """Quantize ``x + err``; returns (codes, scale, new residual)."""
    y = x.astype(jnp.float32) + err
    q, scale = quantize_int8(y)
    return q, scale, y - dequantize_int8(q, scale)


def init_error_tree(params):
    """Zero residual buffers matching ``params``."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, ef=None):
    """Compress a gradient pytree with error feedback.

    Returns ``(payload, ef_new)`` where ``payload = (codes_tree,
    scales_tree)`` is what crosses the wire and ``ef_new`` carries the
    residuals into the next step."""
    if ef is None:
        ef = init_error_tree(grads)
    y = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef)
    # two parallel maps (never tuple-valued leaves): gradient pytrees may
    # legitimately contain tuple nodes, which an is_leaf-on-tuple unzip
    # would mistake for (codes, scale) pairs
    scales = jax.tree.map(_scale_for, y)
    codes = jax.tree.map(_encode, y, scales)
    ef_new = jax.tree.map(
        lambda v, q, s: v - dequantize_int8(q, s), y, codes, scales
    )
    return (codes, scales), ef_new


def decompress_tree(payload):
    codes, scales = payload
    return jax.tree.map(dequantize_int8, codes, scales)
