"""Fused device programs: N statements, one trace, shared scans + pooled
parameter-unified templates.

The fusion engine's back half.  Given the member descriptors the session
assembled (plan, parameter signature, batch bucket per member) plus the
merge pass's sharing maps, this module builds the single **raw closure**
the session jits into the fused executable:

1. rebuild the catalog from the (broadcast) table arguments — exactly as
   the per-statement closure in ``Session._executable`` does;
2. execute every shared **constant** subtree once, innermost-first, into a
   ``fingerprint -> MaskedTable`` pool — the pool builder itself answers
   already-built entries, so a shared sub-subtree beneath two distinct
   shared roots evaluates once, not once per root (nested sharing);
3. execute every **parameter-unified template** once per distinct binding:
   the session passes, per pool group, a ``(d, ...)``-stacked binding
   argument for each canonical hole; the canonical template subtree runs
   ``d`` times (and only ``d`` — the eval counter asserts it) and the
   results stack into a slot-indexed pool;
4. ``vmap`` each member's plan over its own stacked parameter axis, with a
   :class:`SharedScanExecutor` that answers marked constant subtrees from
   the pool and marked template occurrences by gathering the ticket's
   pool slot (a reserved ordinal-spelled slot parameter — see
   ``repro.fuse.merge.slot_param`` — rides the stacked axis); the
   executor propagates itself into subquery/apply sub-evaluation, so
   sharing reaches *inside* correlated bodies;
5. return one ``(mask, columns)`` pair per member — the tagged fused
   result the session slices per-ticket.

Members with an empty parameter signature skip the batch axis entirely
(their tickets are all the same execution): the plan runs once, unbatched,
and every ticket shares the single result — mirroring ``execute_many``'s
parameter-free group handling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.executor import Executor, MaskedTable
from repro.core.interpreter import Interpreter
from repro.fuse.merge import merge_plans
from repro.tables.table import Column, Table

#: reserved stacked-parameter name (filtered out before the executor binds
#: params) — kept for callers that need a dummy batch axis; the leading
#: underscores keep it out of any legal identifier's way
FUSE_PAD = "__fuse_pad__"


class SharedScanExecutor(Executor):
    """An :class:`Executor` that serves marked subtrees from the fused
    program's shared pools instead of re-executing them.

    ``shared_ids`` is the merge pass's ``node_id -> fingerprint`` map and
    ``shared_results`` the constant pool built in step 2 of the fused
    closure (passed by reference: during the pool build itself it is
    partially filled, which is what makes nested sharing work).
    ``template_ids`` maps occurrence ``node_id -> pool-group index``,
    ``template_results`` holds the slot-stacked template pools, and
    ``slot_names`` maps occurrence ``node_id -> reserved slot-parameter
    name`` (the canonical ordinal spelling the session computed — never
    derived from the process-local node id, so persisted fused programs
    re-bind correctly in fresh workers); the occurrence's slot index
    arrives through that reserved parameter.  Any unmarked node executes
    normally — including everything *inside* a shared subtree, which only
    ever runs under the pool builder.

    ``eval_counts`` (shared with every sub-executor) counts pool
    evaluations per key — the instrumentation behind the CSE metamorphic
    tests: a template with ``d`` distinct bindings must log exactly ``d``.
    """

    def __init__(self, catalog, shared_ids, shared_results,
                 template_ids=None, template_results=None,
                 slot_names=None, eval_counts=None, **kwargs):
        super().__init__(catalog, **kwargs)
        self._shared_ids = shared_ids
        self._shared_results = shared_results
        self._template_ids = template_ids or {}
        self._template_results = template_results if template_results is not None else {}
        self._slot_names = slot_names or {}
        self.eval_counts = eval_counts if eval_counts is not None else {}

    def execute_pooled(self, key, node, params=None) -> MaskedTable:
        """One pool evaluation (a constant subtree, or a template under one
        distinct binding), logged in ``eval_counts``."""
        self.eval_counts[key] = self.eval_counts.get(key, 0) + 1
        return self.execute(node, params=params)

    def _sub_executor(self):
        # subquery / correlated-apply sub-evaluation keeps answering from
        # the pools: sharing reaches inside nested plan bodies
        return SharedScanExecutor(
            self.catalog, self._shared_ids, self._shared_results,
            template_ids=self._template_ids,
            template_results=self._template_results,
            slot_names=self._slot_names,
            eval_counts=self.eval_counts,
            udf_column_evaluator=self.udf_column_evaluator,
            use_pallas_agg=self.use_pallas_agg,
        )

    def _exec(self, node, ctx, memo):
        gi = self._template_ids.get(node.node_id)
        if gi is not None:
            hit = self._template_results.get(gi)
            name = self._slot_names.get(node.node_id)
            slot = ctx.params.get(name) if name is not None else None
            if hit is not None and slot is not None:
                mask_stack, col_stacks, dicts = hit
                idx = slot.data
                cols = {
                    c: Column(jnp.take(data, idx, axis=0),
                              jnp.take(valid, idx, axis=0), dicts.get(c))
                    for c, (data, valid) in col_stacks.items()
                }
                return MaskedTable(Table(cols), jnp.take(mask_stack, idx, axis=0))
        fp = self._shared_ids.get(node.node_id)
        if fp is not None:
            hit = self._shared_results.get(fp)
            if hit is not None:
                return hit
        return super()._exec(node, ctx, memo)


def _plans_have_udf_calls(plans) -> bool:
    return any(
        isinstance(e, S.UdfCall)
        for p in plans
        for n in R.walk_plan_deep(p)
        for ex in n.exprs()
        for e in S.walk(ex)
    )


def build_fused_raw(session, members, policy, merged=None, groups=(),
                    member_tmaps=(), slot_names=()):
    """Build the fused raw closure for ``members`` (see module docstring).

    ``groups`` are the session's template pool groups (canonical node,
    hole names/dictionaries, one per (template, binding-signature)),
    ``member_tmaps`` maps each member's occurrence ``node_id`` to its
    group index, and ``slot_names`` maps it to its canonical reserved
    slot-parameter name — all computed host-side in
    ``Session._run_fused`` from the actual ticket bindings, so the
    closure only bakes in structure, never values (the stacked binding
    arrays arrive as jit arguments).

    Returns ``(raw, out_dicts, trace_stats, merged, eval_counts)``: the
    untraced closure, the per-member output-dictionary captures, the
    trace-time stats dict (both filled on first execution, like the
    per-statement executable's), the :class:`~repro.fuse.merge.FusedPlan`,
    and the pool-evaluation counter dict.
    """
    plans = [m.plan for m in members]
    if merged is None:
        merged = merge_plans(plans)

    # iterative hook for UDF calls left in the plans (froid OFF / hybrid);
    # 'scan' mode is the only jit-traceable interpreter (see _executable)
    hook = None
    if _plans_have_udf_calls(plans):
        interp = Interpreter(session.catalog, session.registry, mode="scan")
        hook = interp.eval_udf_call

    meta = {
        tname: {c: col.dictionary for c, col in t.columns.items()}
        for tname, t in session.catalog.items()
    }
    out_dicts: list[dict] = [{} for _ in members]
    trace_stats: dict = {}
    eval_counts: dict = {}

    def raw(table_args, pargs_tuple, targs_tuple):
        catalog = {
            tname: Table(
                {
                    c: Column(data, valid, meta[tname][c])
                    for c, (data, valid) in cols.items()
                }
            )
            for tname, cols in table_args.items()
        }
        # step 2: the constant pool — each distinct cross-statement subtree
        # executes once, outside every member's vmap.  The pool dict is
        # shared by reference with the builder, and entries are built
        # innermost-first, so outer shared subtrees answer their shared
        # descendants from the pool instead of re-evaluating them.
        shared_results: dict = {}
        pool_ex = SharedScanExecutor(
            catalog, merged.shared_ids, shared_results,
            eval_counts=eval_counts,
            udf_column_evaluator=hook, use_pallas_agg=policy.pallas_agg,
        )
        for fp, sub in merged.shared:
            shared_results[fp] = pool_ex.execute_pooled(fp, sub)
        # step 3: template pools — the canonical subtree evaluates once per
        # distinct binding (d is the stacked binding arrays' leading axis)
        template_results: dict = {}
        for gi, g in enumerate(groups):
            targ = targs_tuple[gi]
            d = next(iter(targ.values()))[0].shape[0]
            entries = []
            for j in range(d):
                pv = {
                    h: S.Value(data[j], valid[j], g.hole_dicts.get(h))
                    for h, (data, valid) in targ.items()
                }
                entries.append(pool_ex.execute_pooled((g.fp, g.sig), g.node,
                                                      params=pv))
            cols0 = entries[0].table.columns
            template_results[gi] = (
                jnp.stack([e.mask for e in entries]),
                {
                    c: (jnp.stack([e.table.columns[c].data for e in entries]),
                        jnp.stack([e.table.columns[c].validity()
                                   for e in entries]))
                    for c in cols0
                },
                {c: col.dictionary for c, col in cols0.items()},
            )
        scanned = pool_ex.stats
        outs = []
        for i, (m, pargs) in enumerate(zip(members, pargs_tuple)):
            # hoisted out of the traced per-row closure (executor state is
            # batch-independent)
            ex = SharedScanExecutor(
                catalog, merged.shared_ids, shared_results,
                template_ids=member_tmaps[i] if member_tmaps else {},
                template_results=template_results,
                slot_names=slot_names[i] if slot_names else {},
                eval_counts=eval_counts,
                udf_column_evaluator=hook, use_pallas_agg=policy.pallas_agg,
            )

            def one(pa, i=i, m=m, ex=ex):
                pvals = {
                    name: S.Value(data, valid, m.pdicts.get(name))
                    for name, (data, valid) in pa.items()
                    if name != FUSE_PAD
                }
                out = ex.execute(m.plan, params=pvals)
                for cname, c in out.table.columns.items():
                    out_dicts[i][cname] = c.dictionary  # host metadata
                cols = {
                    cname: (c.data, c.validity())
                    for cname, c in out.table.columns.items()
                }
                return out.mask, cols

            if m.sig:
                outs.append(jax.vmap(one)(pargs))
            else:
                # parameter-free member: one unbatched execution serves
                # every ticket (no per-ticket slicing at delivery); pargs
                # carries only reserved slot params for const-bound
                # template occurrences, if any
                outs.append(one(pargs))
            for k, v in ex.stats.items():
                scanned[k] = scanned.get(k, 0) + v
        trace_stats.update(scanned)
        trace_stats.update(merged.stats)
        trace_stats["cse_pool_evals"] = sum(eval_counts.values())
        return tuple(outs)

    return raw, out_dicts, trace_stats, merged, eval_counts
