"""Backward-compatible facade over :class:`repro.core.session.Session`.

``Database`` was the original entry point, exposing the paper's experiment
axes as boolean kwargs (``froid=…, mode=…, optimize=…``) and re-planning on
every ``run()``.  It is now a thin shim: every call maps its kwargs onto an
:class:`ExecutionPolicy` and routes through the session's plan/executable
caches.  New code should use ``Session.prepare(…).execute(…)`` with the
policy presets (``FROID`` / ``INTERPRETED`` / ``HEKATON``) directly — see
ROADMAP.md §Public API for the deprecation path.
"""
from __future__ import annotations

import warnings

from repro.core import relalg as R
from repro.core.binder import InlineConstraints
from repro.core.policy import ExecutionPolicy
from repro.core.session import QueryResult, RunResult, Session
from repro.tables.table import Table

_UNSET = object()


def _warn_legacy_kwargs(method: str, **kwargs) -> dict:
    """DeprecationWarning for explicitly-passed legacy kwarg spellings and
    the resolved (default-filled) kwarg dict.  The kwargs themselves keep
    working — this is the migration nudge toward Session/ExecutionPolicy."""
    passed = sorted(k for k, v in kwargs.items() if v is not _UNSET)
    if passed:
        warnings.warn(
            f"Database.{method}({', '.join(passed)}=…) kwarg spellings are "
            "deprecated; use Session.prepare/execute with an ExecutionPolicy "
            "preset (FROID / INTERPRETED / HEKATON) — see ROADMAP.md "
            "§Public API",
            DeprecationWarning,
            stacklevel=3,
        )
    return kwargs


class Database:
    def __init__(self, constraints: InlineConstraints | None = None):
        self.session = Session(constraints=constraints)

    # the session owns catalog/registry/constraints; the shim forwards both
    # reads and (legacy benchmark-style) whole-attribute assignment
    @property
    def catalog(self) -> dict[str, Table]:
        return self.session.catalog

    @catalog.setter
    def catalog(self, value):
        self.session.catalog = value

    @property
    def registry(self):
        return self.session.registry

    @registry.setter
    def registry(self, value):
        self.session.registry = value

    @property
    def constraints(self) -> InlineConstraints:
        return self.session.constraints

    @constraints.setter
    def constraints(self, value):
        self.session.constraints = value

    # -- DDL ---------------------------------------------------------------
    # name/table positional-only: columns may be called "name"/"table"
    def create_table(self, name: str, table: Table | None = None, /, **arrays):
        return self.session.create_table(name, table, **arrays)

    def create_function(self, udf):
        return self.session.create_function(udf)

    # -- planning ----------------------------------------------------------
    def plan_for(self, query, froid: bool = True, optimize: bool = True) -> R.RelNode:
        policy = ExecutionPolicy.from_kwargs(froid=froid, optimize=optimize)
        return self.session.prepare(query, policy).plan

    def explain(self, query, froid: bool = True, optimize: bool = True) -> str:
        policy = ExecutionPolicy.from_kwargs(froid=froid, optimize=optimize)
        return self.session.explain(query, policy)

    # -- execution ---------------------------------------------------------
    def run(
        self,
        query,
        froid=_UNSET,
        mode=_UNSET,
        optimize=_UNSET,
        params: dict | None = None,
        jit_statements=_UNSET,
        pallas_agg=_UNSET,
    ) -> QueryResult:
        """Eager execution with the legacy kwarg axes (deprecated spelling
        of ``session.execute(query, policy, params)``)."""
        kw = _warn_legacy_kwargs(
            "run", froid=froid, mode=mode, optimize=optimize,
            jit_statements=jit_statements, pallas_agg=pallas_agg,
        )
        policy = ExecutionPolicy.from_kwargs(
            froid=kw["froid"] if kw["froid"] is not _UNSET else True,
            mode=kw["mode"] if kw["mode"] is not _UNSET else "python",
            optimize=kw["optimize"] if kw["optimize"] is not _UNSET else True,
            jit_statements=(kw["jit_statements"]
                            if kw["jit_statements"] is not _UNSET else True),
            pallas_agg=(kw["pallas_agg"]
                        if kw["pallas_agg"] is not _UNSET else False),
            compiled=False,
        )
        return self.session.execute(query, policy, params=params)

    def run_compiled(self, query, froid=_UNSET, mode=_UNSET, optimize=_UNSET):
        """Deprecated spelling of ``session.prepare(…)``: returns the raw
        compiled callable plus the plan (the old warm-cache benchmark
        interface).  ``PreparedStatement`` itself is the replacement."""
        kw = _warn_legacy_kwargs(
            "run_compiled", froid=froid, mode=mode, optimize=optimize,
        )
        policy = ExecutionPolicy.from_kwargs(
            froid=kw["froid"] if kw["froid"] is not _UNSET else True,
            mode=kw["mode"] if kw["mode"] is not _UNSET else "scan",
            optimize=kw["optimize"] if kw["optimize"] is not _UNSET else True,
            compiled=True,
        )
        ps = self.session.prepare(query, policy)
        return ps, ps.plan


__all__ = ["Database", "QueryResult", "RunResult"]
