"""Batched invocation engine: `execute_many` / `execute_async` and the
coalescing microbatch scheduler.

Covers the ISSUE-2 contract: element-wise identity with the serial execute
loop, shape/dtype bucketing and cache keying across batch sizes and mixed
parameter signatures, catalog-mutation invalidation between execute_many
calls, async future correctness, and scheduler coalescing/flush behavior.
"""
import numpy as np
import pytest

from repro.core import (
    FROID,
    HEKATON,
    INTERPRETED,
    AsyncResult,
    ExecutionPolicy,
    Session,
    UdfBuilder,
    batch_bucket,
    col,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)
from repro.serve.scheduler import CoalescingScheduler


def _populate(db, n_detail=2000, n_t=200, seed=0):
    rng = np.random.default_rng(seed)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 50, n_detail),
        d_val=rng.uniform(0, 100, n_detail).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 50, n_t))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())


def _q():
    return (
        scan("T")
        .filter(col("a") < param("cutoff"))
        .compute(v=udf("key_total", col("a")))
        .project("v")
    )


def _assert_same(serial, batched):
    assert len(serial) == len(batched)
    for s, b in zip(serial, batched):
        np.testing.assert_array_equal(
            np.asarray(s.masked.mask), np.asarray(b.masked.mask)
        )
        np.testing.assert_allclose(
            np.asarray(s.masked.table.columns["v"].data),
            np.asarray(b.masked.table.columns["v"].data),
            rtol=1e-5,
        )


@pytest.fixture
def db():
    s = Session()
    _populate(s)
    return s


# ---------------------------------------------------------------------------
# element-wise identity with the serial loop
# ---------------------------------------------------------------------------


def test_execute_many_matches_serial_loop(db):
    stmt = db.prepare(_q(), FROID)
    params_list = [{"cutoff": k} for k in (3, 17, 42, 50, 1, 29, 8)]
    serial = [stmt.execute(params=p) for p in params_list]
    batched = stmt.execute_many(params_list)
    _assert_same(serial, batched)
    st = batched[0].stats
    assert st["batched"] and st["batch_size"] == 7 and st["batch_bucket"] == 8
    assert "dispatch_s" in st and "sync_s" in st


def test_execute_many_order_preserved(db):
    stmt = db.prepare(_q(), FROID)
    # mixed signatures interleaved: results must come back in input order
    params_list = [{"cutoff": 3}, {"cutoff": 10.5}, {"cutoff": 40},
                   {"cutoff": 0.5}, {"cutoff": 22}]
    batched = stmt.execute_many(params_list)
    serial = [stmt.execute(params=p) for p in params_list]
    _assert_same(serial, batched)


def test_execute_many_empty_and_paramless(db):
    stmt = db.prepare(_q(), FROID)
    assert stmt.execute_many([]) == []
    q = scan("T").compute(v=udf("key_total", col("a")))
    s2 = db.prepare(q, FROID)
    rs = s2.execute_many([None, {}, None])
    assert len(rs) == 3
    # one execution serves the group, but results are distinct shells
    # (per-result stats/annotations must not alias)
    assert len({id(r) for r in rs}) == 3
    assert len({id(r.stats) for r in rs}) == 3
    a = np.asarray(rs[0].masked.table.columns["v"].data)
    for r in rs[1:]:
        np.testing.assert_array_equal(
            a, np.asarray(r.masked.table.columns["v"].data)
        )


def test_execute_many_eager_policy_falls_back_serial(db):
    stmt = db.prepare(_q(), INTERPRETED)
    params_list = [{"cutoff": 5}, {"cutoff": 25}]
    rs = stmt.execute_many(params_list)
    serial = [stmt.execute(params=p) for p in params_list]
    _assert_same(serial, rs)
    assert "batched" not in rs[0].stats


def test_execute_many_hekaton(db):
    stmt = db.prepare(_q(), HEKATON)
    params_list = [{"cutoff": k} for k in (4, 31, 12)]
    _assert_same([stmt.execute(params=p) for p in params_list],
                 stmt.execute_many(params_list))


# ---------------------------------------------------------------------------
# bucketing + cache keying
# ---------------------------------------------------------------------------


def test_batch_bucket_shape():
    assert [batch_bucket(n, 1024) for n in (1, 2, 3, 5, 8, 9, 1000)] == \
        [1, 2, 4, 8, 8, 16, 1024]
    assert batch_bucket(2000, 64) == 64  # capped at max_batch
    with pytest.raises(ValueError):
        batch_bucket(0, 64)


def test_same_bucket_reuses_vmapped_executable(db):
    stmt = db.prepare(_q(), FROID)
    stmt.execute_many([{"cutoff": k} for k in (1, 2, 3)])  # bucket 4
    misses = db.cache_stats["batch_misses"]
    r = stmt.execute_many([{"cutoff": k} for k in (9, 8, 7, 6)])  # bucket 4
    assert db.cache_stats["batch_misses"] == misses
    assert r[0].cache_hit and r[0].stats["batch_bucket"] == 4
    # a different bucket is a new specialization
    stmt.execute_many([{"cutoff": k} for k in range(5)])  # bucket 8
    assert db.cache_stats["batch_misses"] == misses + 1


def test_mixed_signatures_split_into_buckets(db):
    stmt = db.prepare(_q(), FROID)
    params_list = ([{"cutoff": k} for k in (1, 2, 3)]
                   + [{"cutoff": float(k)} for k in (4.0, 5.0)])
    before = db.cache_stats["batch_misses"]
    rs = stmt.execute_many(params_list)
    # two signatures -> two sub-batches -> two vmapped executables
    assert db.cache_stats["batch_misses"] == before + 2
    assert rs[0].stats["batch_size"] == 3 and rs[3].stats["batch_size"] == 2
    _assert_same([stmt.execute(params=p) for p in params_list], rs)


def test_max_batch_chunks(db):
    stmt = db.prepare(_q(), FROID.batched(max_batch=4))
    params_list = [{"cutoff": int(k)} for k in range(10)]
    rs = stmt.execute_many(params_list)
    sizes = [r.stats["batch_size"] for r in rs]
    assert sizes == [4, 4, 4, 4, 4, 4, 4, 4, 2, 2]
    assert all(r.stats["batch_bucket"] <= 4 for r in rs)
    _assert_same([stmt.execute(params=p) for p in params_list], rs)


def test_batched_policy_knobs_are_not_identity():
    assert FROID.batched(max_batch=8) == FROID
    assert FROID.batched(max_batch=8).fingerprint() == FROID.fingerprint()
    assert FROID.batched(max_batch=8).max_batch == 8
    assert not INTERPRETED.allow_async


def test_prepare_distinct_batch_knobs_do_not_alias(db):
    """Two prepares differing only in batch knobs return distinct handles
    carrying their own knobs, while still sharing the plan/executable
    caches underneath (the knobs are excluded from fingerprint())."""
    s1 = db.prepare(_q(), FROID)
    s2 = db.prepare(_q(), FROID.batched(max_batch=2, allow_async=False))
    assert s1 is not s2
    assert s1.policy.max_batch == FROID.max_batch
    assert s2.policy.max_batch == 2 and not s2.policy.allow_async
    # knob changes must actually take effect on the returned handle
    rs = s2.execute_many([{"cutoff": k} for k in range(5)])
    assert all(r.stats["batch_bucket"] <= 2 for r in rs)
    assert s2.execute_async(params={"cutoff": 3}).done()  # degraded to sync
    # underneath, the compiled executable is shared: executing via s2
    # after s1 is an exec-cache hit, not a re-specialization
    s1.execute(params={"cutoff": 9})
    misses = db.cache_stats["exec_misses"]
    r = s2.execute(params={"cutoff": 9})
    assert db.cache_stats["exec_misses"] == misses and r.cache_hit


# ---------------------------------------------------------------------------
# chunk pipelining
# ---------------------------------------------------------------------------


def test_chunked_dispatches_are_pipelined(db):
    """All chunks dispatch before the end-of-call barrier: every result
    reports the call's chunk count, and parity with the serial loop holds."""
    stmt = db.prepare(_q(), FROID.batched(max_batch=4))
    params_list = [{"cutoff": int(k)} for k in range(10)]
    rs = stmt.execute_many(params_list)
    assert all(r.stats["pipelined_chunks"] == 3 for r in rs)
    _assert_same([stmt.execute(params=p) for p in params_list], rs)
    # single-chunk calls still report (a pipeline of one)
    r1 = stmt.execute_many([{"cutoff": 5}])
    assert r1[0].stats["pipelined_chunks"] == 1


def test_pipelining_bounded_by_max_inflight(db):
    """max_inflight=1 degrades to sync-per-chunk dispatch order but stays
    element-wise identical."""
    stmt = db.prepare(_q(), FROID.batched(max_batch=2, max_inflight=1))
    params_list = [{"cutoff": int(k)} for k in range(7)]
    rs = stmt.execute_many(params_list)
    assert rs[0].stats["pipelined_chunks"] == 4
    _assert_same([stmt.execute(params=p) for p in params_list], rs)


# ---------------------------------------------------------------------------
# adaptive coalescing
# ---------------------------------------------------------------------------


def test_adaptive_window_tracks_arrival_rate(db):
    """Fast arrivals shrink the effective window to ~hold×EMA; the batch
    drains as soon as that passes instead of waiting out the full window."""
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=64, window_s=10.0, clock=clock,
                                adaptive=True, adaptive_alpha=0.5,
                                adaptive_hold=4.0)
    stmt = db.prepare(_q(), FROID)
    ts = []
    for k in (1, 2, 3):
        ts.append(sched.submit(stmt, {"cutoff": k}))
        clock.advance(0.01)
    assert abs(sched.ema_gap_s(stmt) - 0.01) < 1e-12
    assert abs(sched.effective_window(stmt) - 0.04) < 1e-12
    assert sched.poll() == 0  # 0.02 elapsed since open < 0.04
    clock.advance(0.02)       # 0.04+ since the group opened
    assert sched.poll() == 3  # drained at the adaptive window, not 10s
    _assert_same([stmt.execute(params={"cutoff": k}) for k in (1, 2, 3)],
                 [t.result() for t in ts])


def test_adaptive_window_clamped_to_configured_window(db):
    """Sparse traffic degrades to the configured window — the EMA never
    *extends* the latency bound."""
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=64, window_s=0.05, clock=clock,
                                adaptive=True)
    stmt = db.prepare(_q(), FROID)
    sched.submit(stmt, {"cutoff": 1})
    clock.advance(100.0)      # huge gap -> EMA far above the window
    sched.poll()              # drains the first (window long expired)
    sched.submit(stmt, {"cutoff": 2})
    assert sched.ema_gap_s(stmt) == 100.0
    assert sched.effective_window(stmt) == 0.05  # clamped
    # off by default: the plain scheduler never adapts
    plain = CoalescingScheduler(window_s=0.05, clock=clock)
    assert not plain.adaptive and plain.effective_window(stmt) == 0.05
    sched.flush()


def test_adaptive_window_is_per_statement(db):
    """Round-robin traffic over many statements must not shrink any one
    statement's window below its own refill rate: the EMA tracks the
    same-statement gap (here 3×global), so groups still coalesce instead
    of degrading to batch-size-1 drains."""
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=64, window_s=10.0, clock=clock,
                                adaptive=True, adaptive_hold=4.0)
    stmts = [db.prepare(_q(), FROID),
             db.prepare(scan("T").filter(col("a") < param("cutoff")), FROID),
             db.prepare(scan("T").compute(b=col("a") * 2), FROID)]
    for wave in range(3):           # s0 s1 s2 s0 s1 s2 ... gap 0.01 global
        for s in stmts:
            sched.submit(s, {"cutoff": wave + 1} if s is not stmts[2] else {})
            clock.advance(0.01)
    for s in stmts:
        assert abs(sched.ema_gap_s(s) - 0.03) < 1e-12  # per-stmt, not 0.01
        assert abs(sched.effective_window(s) - 0.12) < 1e-12
    # nothing drained mid-stream: every group kept coalescing its wave
    assert sched.stats["batches"] == 0 and sched.pending == 9
    assert sched.flush() == 9
    assert all(sched.stats[k] == v for k, v in
               [("batches", 3), ("flush_window", 0)])


# ---------------------------------------------------------------------------
# invalidation
# ---------------------------------------------------------------------------


def test_catalog_mutation_invalidates_between_execute_many_calls(db):
    stmt = db.prepare(_q(), FROID)
    params_list = [{"cutoff": k} for k in (10, 20, 30)]
    r1 = stmt.execute_many(params_list)
    # warm second call
    assert stmt.execute_many(params_list)[0].cache_hit
    # DDL: replace the detail table -> batched executables re-specialize
    rng = np.random.default_rng(99)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 50, 2000),
        d_val=rng.uniform(0, 100, 2000).astype(np.float32),
    )
    r2 = stmt.execute_many(params_list)
    assert not r2[0].cache_hit
    _assert_same([stmt.execute(params=p) for p in params_list], r2)
    # new data actually flowed through
    a1 = np.asarray(r1[2].masked.table.columns["v"].data)
    a2 = np.asarray(r2[2].masked.table.columns["v"].data)
    assert not np.allclose(a1, a2)


# ---------------------------------------------------------------------------
# async futures
# ---------------------------------------------------------------------------


def test_execute_async_matches_sync(db):
    stmt = db.prepare(_q(), FROID)
    fut = stmt.execute_async(params={"cutoff": 33})
    assert isinstance(fut, AsyncResult)
    r = fut.result()
    s = stmt.execute(params={"cutoff": 33})
    _assert_same([s], [r])
    assert r.stats.get("async") and "sync_s" in r.stats
    assert fut.done()
    assert fut.result() is r  # idempotent


def test_execute_async_pipelined_dispatches(db):
    stmt = db.prepare(_q(), FROID)
    params_list = [{"cutoff": k} for k in (2, 12, 22, 32)]
    futs = [stmt.execute_async(params=p) for p in params_list]
    rs = [f.result() for f in futs]
    _assert_same([stmt.execute(params=p) for p in params_list], rs)


def test_execute_async_disallowed_degrades_to_sync(db):
    stmt = db.prepare(_q(), FROID.batched(allow_async=False))
    fut = stmt.execute_async(params={"cutoff": 11})
    assert fut.done()  # executed synchronously behind the same interface
    _assert_same([stmt.execute(params={"cutoff": 11})], [fut.result()])
    # eager policies likewise
    fut2 = db.prepare(_q(), INTERPRETED).execute_async(params={"cutoff": 11})
    assert fut2.done()
    assert "async" not in fut2.result().stats


# ---------------------------------------------------------------------------
# async backpressure
# ---------------------------------------------------------------------------


def test_async_backpressure_bounds_inflight():
    """A runaway producer stalls at policy.max_inflight: each dispatch past
    the bound first syncs the oldest in-flight one, so the session never
    holds more than the bound."""
    db = Session()
    _populate(db, n_detail=300_000, n_t=2000)  # device work outlasts dispatch
    stmt = db.prepare(_q(), FROID.batched(max_inflight=2))
    futs = []
    for k in range(8):
        futs.append(stmt.execute_async(params={"cutoff": int(k % 50)}))
        assert db.inflight <= 2
    assert db.async_stats["inflight_peak"] <= 2
    rs = [f.result() for f in futs]
    assert db.inflight == 0  # result() released every slot
    _assert_same(
        [stmt.execute(params={"cutoff": int(k % 50)}) for k in range(8)], rs
    )


def test_admit_async_blocks_at_bound():
    """Deterministic bound check: with the queue full of never-ready
    dispatches, admission pops (and waits on) exactly the oldest."""
    db = Session()

    class _Stub:
        _marker = None
        _released = False

        def done(self):
            return False

    s1, s2 = _Stub(), _Stub()
    db._inflight.extend([s1, s2])
    db._admit_async(2)
    assert db.async_stats["inflight_waits"] == 1
    assert s1._released and not s2._released
    assert list(db._inflight) == [s2]
    db._admit_async(2)  # below the bound now: no further wait
    assert db.async_stats["inflight_waits"] == 1


def test_async_result_releases_slot(db):
    stmt = db.prepare(_q(), FROID.batched(max_inflight=4))
    fut = stmt.execute_async(params={"cutoff": 13})
    assert db.inflight == 1
    fut.result()
    assert db.inflight == 0
    fut.result()  # idempotent: no double release / no error
    assert db.inflight == 0


def test_async_degraded_results_hold_no_slot(db):
    stmt = db.prepare(_q(), FROID.batched(allow_async=False, max_inflight=1))
    futs = [stmt.execute_async(params={"cutoff": 5}) for _ in range(3)]
    assert db.inflight == 0  # synchronous execution never occupies a slot
    assert db.async_stats["inflight_waits"] == 0
    for f in futs:
        f.result()


def test_batched_max_inflight_knob_not_identity():
    assert FROID.batched(max_inflight=2) == FROID
    assert FROID.batched(max_inflight=2).fingerprint() == FROID.fingerprint()
    assert FROID.batched(max_inflight=2).max_inflight == 2


# ---------------------------------------------------------------------------
# cache invalidation between submit() and drain()
# ---------------------------------------------------------------------------


def test_ddl_between_submit_and_drain_not_stale(db):
    """DDL while tickets sit in a pending microbatch must re-specialize at
    drain time — the env token is read when the batch drains, not when the
    requests were submitted."""
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=64, window_s=10.0, clock=clock)
    stmt = db.prepare(_q(), FROID)
    params_list = [{"cutoff": k} for k in (10, 20, 49)]
    stmt.execute_many(params_list)  # warm the pre-DDL vmapped executable
    tickets = [sched.submit(stmt, p) for p in params_list]
    rng = np.random.default_rng(17)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 50, 2000),
        d_val=rng.uniform(0, 100, 2000).astype(np.float32),
    )
    assert sched.flush() == 3
    results = [t.result() for t in tickets]
    assert not results[0].cache_hit  # fresh specialization, not the warm one
    _assert_same([stmt.execute(params=p) for p in params_list], results)


def test_catalog_poke_between_submit_and_drain_not_stale(db):
    """Direct catalog[...] pokes (no DDL call) between submit and drain
    likewise reach the drained batch."""
    from repro.tables.table import Table

    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=64, window_s=10.0, clock=clock)
    stmt = db.prepare(_q(), FROID)
    params = {"cutoff": 49}
    warm = stmt.execute(params=params)
    t = sched.submit(stmt, params)
    rng = np.random.default_rng(23)
    poked = Table.from_arrays(
        d_key=rng.integers(0, 50, 2000),
        d_val=rng.uniform(0, 100, 2000).astype(np.float32),
    )
    poked.compute_stats()
    db.catalog["detail"] = poked
    sched.flush()
    r = t.result()
    _assert_same([stmt.execute(params=params)], [r])
    m = np.asarray(r.masked.mask)
    assert not np.allclose(
        np.asarray(warm.masked.table.columns["v"].data)[m],
        np.asarray(r.masked.table.columns["v"].data)[m],
    )


def test_udf_replacement_between_submit_and_drain_not_stale(db):
    """Re-registering a UDF between submit and drain re-plans: the drained
    batch runs the new body."""
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=64, window_s=10.0, clock=clock)
    stmt = db.prepare(_q(), FROID)
    params = {"cutoff": 49}
    t = sched.submit(stmt, params)
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.return_(lit(-1.0))  # replacement body: constant
    db.create_function(u.build())
    sched.flush()
    r = t.result()
    m = np.asarray(r.masked.mask)
    np.testing.assert_allclose(
        np.asarray(r.masked.table.columns["v"].data)[m], -1.0
    )


# ---------------------------------------------------------------------------
# coalescing microbatch scheduler
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def test_scheduler_coalesces_and_flushes_on_window(db):
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=64, window_s=0.010, clock=clock)
    stmt = db.prepare(_q(), FROID)
    t1 = sched.submit(stmt, {"cutoff": 5})
    t2 = sched.submit(stmt, {"cutoff": 25})
    assert sched.pending == 2 and not t1.done()
    assert sched.poll() == 0  # window not expired: still coalescing
    clock.advance(0.011)
    assert sched.poll() == 2  # window expired: drained as one batch
    assert t1.done() and t2.done()
    assert sched.stats["batches"] == 1 and sched.stats["flush_window"] == 1
    _assert_same(
        [stmt.execute(params={"cutoff": 5}), stmt.execute(params={"cutoff": 25})],
        [t1.result(), t2.result()],
    )
    assert t1.result().stats["batch_size"] == 2


def test_scheduler_flush_on_full_batch(db):
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=3, window_s=10.0, clock=clock)
    stmt = db.prepare(_q(), FROID)
    ts = [sched.submit(stmt, {"cutoff": k}) for k in (1, 2)]
    assert sched.pending == 2
    ts.append(sched.submit(stmt, {"cutoff": 3}))  # hits max_batch
    assert sched.pending == 0 and all(t.done() for t in ts)
    assert sched.stats["flush_full"] == 1


def test_scheduler_result_forces_drain(db):
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=64, window_s=10.0, clock=clock)
    stmt = db.prepare(_q(), FROID)
    t = sched.submit(stmt, {"cutoff": 7})
    assert not t.done()
    r = t.result()  # no traffic, huge window: consumer never deadlocks
    assert t.done() and sched.stats["flush_forced"] == 1
    _assert_same([stmt.execute(params={"cutoff": 7})], [r])


def test_scheduler_window_defaults_from_policy(db):
    clock = FakeClock()
    sched = CoalescingScheduler(clock=clock)
    stmt = db.prepare(_q(), FROID.batched(max_batch=2, coalesce_window_s=5.0))
    sched.submit(stmt, {"cutoff": 1})
    clock.advance(1.0)
    assert sched.poll() == 0  # policy window (5s) not expired
    sched.submit(stmt, {"cutoff": 2})  # policy max_batch (2) -> flush-on-full
    assert sched.pending == 0 and sched.stats["flush_full"] == 1


def test_scheduler_groups_per_statement(db):
    clock = FakeClock()
    sched = CoalescingScheduler(max_batch=64, window_s=10.0, clock=clock)
    s1 = db.prepare(_q(), FROID)
    s2 = db.prepare(scan("T").filter(col("a") < param("cutoff")), FROID)
    t1 = sched.submit(s1, {"cutoff": 5})
    t2 = sched.submit(s2, {"cutoff": 5})
    assert sched.pending == 2
    assert sched.flush() == 2
    assert sched.stats["batches"] == 2  # one per statement, not merged
    assert t1.done() and t2.done()


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------


def test_admission_coalesced_matches_tick_path():
    from repro.serve.admission import AdmissionPolicy

    reqs = {
        "tier": np.array([0, 1, 2, 0, 2]),
        "prompt_len": np.array([100, 3000, 9000, 40000, 100]),
        "max_new_tokens": np.array([50, 2000, 8000, 10, 100]),
        "temperature": np.array([0.5, 1.5, -1.0, 0.7, 3.0], np.float32),
    }
    ap = AdmissionPolicy(froid=True)
    tick = ap.evaluate(reqs)
    co = ap.evaluate_coalesced(reqs)
    np.testing.assert_array_equal(tick["admit"], co["admit"])
    np.testing.assert_array_equal(tick["granted"], co["granted"])
    np.testing.assert_allclose(tick["temp"], co["temp"], rtol=1e-6)
    assert ap.scheduler.stats["batches"] >= 1
    # the request statement stayed prepared: a second wave is all warm
    before = ap._request_session.cache_stats["batch_misses"]
    ap.evaluate_coalesced(reqs)
    assert ap._request_session.cache_stats["batch_misses"] == before


def test_admission_coalesced_load_shedding_parity():
    """Under pressure (depth > 512, long prompts) the coalesced path must
    shed exactly the requests the tick path sheds — every ticket sees the
    whole wave's queue depth, not its own submit position."""
    from repro.serve.admission import AdmissionPolicy

    n = 600
    rng = np.random.default_rng(3)
    reqs = {
        "tier": rng.integers(0, 3, n),
        "prompt_len": np.where(rng.random(n) < 0.5, 9000, 100),
        "max_new_tokens": np.full(n, 64),
        "temperature": np.full(n, 0.5, np.float32),
    }
    ap = AdmissionPolicy(froid=True)
    tick = ap.evaluate(reqs)
    co = ap.evaluate_coalesced(reqs)
    np.testing.assert_array_equal(tick["admit"], co["admit"])
    assert not tick["admit"][reqs["prompt_len"] == 9000].any()  # shed
    assert tick["admit"][reqs["prompt_len"] == 100].all()


def test_database_run_legacy_kwargs_warn():
    from repro.core import Database

    db = Database()
    db.create_table("t", x=np.arange(5))
    q = scan("t").filter(col("x") < lit(3))
    with pytest.warns(DeprecationWarning, match="froid"):
        db.run(q, froid=True)
    with pytest.warns(DeprecationWarning, match="mode"):
        db.run(q, mode="python")
    with pytest.warns(DeprecationWarning):
        db.run_compiled(q, froid=True)
    # the new spelling stays silent
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        db.run(q, params=None)
        db.session.execute(q, FROID)
