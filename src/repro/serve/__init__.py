from repro.serve.engine import Request, ServeEngine
from repro.serve.admission import AdmissionPolicy
from repro.serve.fleet import FleetEngine, FleetWorker
from repro.serve.scheduler import CoalescingScheduler, Ticket

__all__ = ["Request", "ServeEngine", "AdmissionPolicy", "FleetEngine",
           "FleetWorker", "CoalescingScheduler", "Ticket"]
