"""Prepared-statement lifecycle: cold prepare+first-execute vs warm execute
across the three ExecutionPolicy presets (the engine-API view of the
paper's "plan once, execute many" economics).

Emits the same `name,us_per_call,derived` rows as the rest of the harness:

    PYTHONPATH=src python -m benchmarks.bench_prepared [--quick]

For each preset: ``cold`` is a fresh Session paying bind + optimize (+ jit
for compiling policies) + one execution; ``warm`` is the median execute on
the same PreparedStatement afterwards (cache_hit asserted).  ``param_swap``
re-executes warm with a different parameter *value* (same signature — no
re-specialization).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, time_run
from repro.core import (
    FROID,
    HEKATON,
    INTERPRETED,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)

N_ROWS = 2_000
N_ROWS_INTERP = 200  # per-row interpretation is the slow quadrant
M_ROWS = 20_000


def _setup(n_rows: int) -> Session:
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 400, M_ROWS),
        d_val=rng.uniform(0, 100, M_ROWS).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 400, n_rows))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())
    return db


def _q():
    return (
        scan("T")
        .filter(col("a") < param("cutoff"))
        .compute(v=udf("key_total", col("a")))
        .project("v")
    )


def run(quick: bool = False):
    presets = [FROID, HEKATON, INTERPRETED]
    for policy in presets:
        n = N_ROWS_INTERP if policy is INTERPRETED else N_ROWS
        db = _setup(n)
        params = {"cutoff": 400}

        t0 = time.perf_counter()
        stmt = db.prepare(_q(), policy)
        r_cold = stmt.execute(params=params)
        t_cold = time.perf_counter() - t0
        assert not r_cold.cache_hit
        emit(f"prepared/{policy.name}/cold", t_cold * 1e6,
             f"bind+optimize{'+jit' if policy.compile_plan else ''}+run "
             f"rows={n}")

        iters = 1 if (quick or policy is INTERPRETED) else 3
        t_warm = time_run(lambda: stmt.execute(params=params).masked.mask,
                          warmup=1, iters=iters)
        r_warm = stmt.execute(params=params)
        assert r_warm.cache_hit, policy.name
        emit(f"prepared/{policy.name}/warm", t_warm * 1e6,
             f"cold/warm={t_cold/t_warm:.0f}x cache_hit={r_warm.cache_hit}")

        # changed parameter value, unchanged signature: stays warm
        t_swap = time_run(
            lambda: stmt.execute(params={"cutoff": 200}).masked.mask,
            warmup=1, iters=iters,
        )
        r_swap = stmt.execute(params={"cutoff": 200})
        assert r_swap.cache_hit, policy.name
        emit(f"prepared/{policy.name}/param_swap", t_swap * 1e6,
             f"same signature, no re-bind")


if __name__ == "__main__":
    run()
