"""Online cost router: measured wave costs + static estimates → the
cheapest configuration per statement and per drain wave.

One :class:`CostRouter` attaches lazily to a :class:`~repro.core.session.
Session` (``Session._ensure_router``; statements opt in with
``policy.routed`` / the ``ROUTED`` preset).  It learns from the stats
seams the engine already has:

* ``execute_many`` chunk finalization → per-wave ``many`` samples keyed
  by (statement, policy, signature, shard layout, bucket);
* serial compiled ``execute`` → ``serial`` samples per statement;
* fused drains → ``fused`` samples keyed by the wave's canonical member
  statement set (plus the wave's CSE meta: bindings, ticket refs).

Samples taken while the resilience ladder is degrading a wave or a
breaker is open are **excluded** (:meth:`CostRouter.suppress` — the
ladder wraps retries/demoted tiers in it), so faults never poison the
model; ``stats['samples_excluded']`` counts what was dropped.

Routing axes (each decision is appended to a bounded log and surfaced via
``Session.cost_stats``):

* **policy** (:meth:`choose_policy`) — FROID vs HEKATON identity for a
  routed statement.  Measured costs win when both candidates have been
  observed on the same kind of path; otherwise the static estimates
  decide, and an unmeasured alternative is only *explored* when its
  estimate beats the incumbent's by a clear margin (exploring a
  same-or-worse-estimate alternative would pay a compile for nothing).
* **bucket** (:meth:`choose_bucket`) — ride an already-measured larger
  batch bucket instead of cold-compiling the natural power-of-two one,
  whenever the measured wave cost of the warm bucket undercuts the
  estimated compile + run cost of the cold one.
* **fuse** (:meth:`choose_fuse`) — fused wave vs per-statement drains.
  Both arms are explored once (fused first — the engine's static
  default), then the measured per-wave totals decide.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
from collections import deque

from repro.core.policy import ExecutionPolicy
from repro.cost.model import (
    estimate_compile_s,
    estimate_plan,
    estimate_statement_s,
)

#: EMA smoothing for measured per-wave costs
EMA_ALPHA = 0.4

#: explore an unmeasured policy alternative only when its static estimate
#: beats the incumbent's by at least this factor (strictly below 1.0:
#: an equal-estimate alternative never justifies a fresh compile)
EXPLORE_MARGIN = 0.9

#: once both fuse arms are measured, flip away from the incumbent only
#: when the alternative is at least this much cheaper — near-tie arms
#: would otherwise flip-flop on measurement noise every wave
FUSE_MARGIN = 0.9

#: bounded decision log length
DECISION_LOG = 256


def _digest(obj) -> str:
    """Stable short digest of a structural key (fingerprints are large
    nested tuples; ``cost_stats`` readers want something printable)."""
    return hashlib.sha1(repr(obj).encode()).hexdigest()[:10]


def _fused_key(member_fps) -> tuple:
    """Canonical measured-config key for a fused wave: the *distinct*
    member statement fingerprints, sorted.  Deduped because the observe
    seam sees one fp per (statement, signature) member while the routing
    seam sees one per statement — the same wave must hit the same key."""
    return ("fused", tuple(sorted(set(member_fps), key=repr)))


@dataclasses.dataclass
class _Ema:
    """One measured-configuration record: EMA of per-wave seconds."""

    wave_s: float
    n: int = 1
    last_s: float = 0.0

    def update(self, s: float) -> None:
        self.wave_s = EMA_ALPHA * s + (1.0 - EMA_ALPHA) * self.wave_s
        self.n += 1
        self.last_s = s


class CostRouter:
    """See module docstring.  All state is host-side and per-session."""

    def __init__(self, session):
        self.session = session
        #: measured-config EMAs: key -> _Ema.  Keys:
        #:   ("many", query_fp, pol_fp, sig, shard_token, bucket)
        #:   ("serial", query_fp, pol_fp)
        #:   ("fused", member_fps_sorted)
        self.measured: dict[tuple, _Ema] = {}
        #: coarse per-ticket EMAs for cross-configuration comparisons:
        #:   (kind, query_fp, pol_fp) -> _Ema of seconds *per ticket*
        self.per_ticket: dict[tuple, _Ema] = {}
        self.estimates: dict[tuple, float] = {}  # estimate memo
        #: warm-bucket index for :meth:`choose_bucket`: the "many" keys of
        #: ``measured`` grouped by prefix -> {bucket: shared _Ema}, so the
        #: per-chunk lookup is one dict get instead of a full-table scan
        self._warm_many: dict[tuple, dict[int, _Ema]] = {}
        #: policy-candidate memo: id(base policy) -> (base, [(cand, fp)])
        self._cand_memo: dict[int, tuple] = {}
        #: estimate-verdict memo: (query_fp, base_fp, catalog_token) ->
        #: chosen policy (estimates are static per catalog version)
        self._verdicts: dict[tuple, ExecutionPolicy] = {}
        #: last measured fuse verdict per fused key (hysteresis state)
        self._fuse_last: dict[tuple, bool] = {}
        #: bumped when a *new* coarse per-ticket key appears — the only
        #: evidence event that can change a not-yet-measured-both policy
        #: verdict, so it (plus the catalog token) validates the fast path
        self._pt_new = 0
        #: steady-state verdict fast path: id(stmt) -> (stmt, chosen,
        #: pt_new, catalog_token); skipped for value-dependent verdicts
        #: (both candidates measured — EMA updates may flip those)
        self._policy_fast: dict[int, tuple] = {}
        self.decisions: deque = deque(maxlen=DECISION_LOG)
        self.stats = {
            "samples": 0, "samples_excluded": 0, "decisions": 0,
            "policy_reroutes": 0, "bucket_rides": 0,
            "waves_fused": 0, "waves_unfused": 0,
        }
        self._suppress_depth = 0

    # -- fault-window exclusion ---------------------------------------------
    @contextlib.contextmanager
    def suppress(self):
        """Samples observed inside this context are counted but dropped —
        the resilience ladder wraps retries, demoted tiers and
        breaker-open windows in it so fault-time costs never train the
        model.  Re-entrant."""
        self._suppress_depth += 1
        try:
            yield self
        finally:
            self._suppress_depth -= 1

    @property
    def suppressed(self) -> bool:
        return self._suppress_depth > 0

    # -- sample intake -------------------------------------------------------
    def _observe(self, key: tuple, wave_s: float, *, coarse: tuple | None,
                 tickets: int) -> None:
        if self.suppressed:
            self.stats["samples_excluded"] += 1
            return
        self.stats["samples"] += 1
        ent = self.measured.get(key)
        if ent is None:
            ent = self.measured[key] = _Ema(wave_s, last_s=wave_s)
            if key[0] == "many":
                self._warm_many.setdefault(key[:-1], {})[key[-1]] = ent
        else:
            ent.update(wave_s)
        if coarse is not None and tickets > 0:
            per = wave_s / tickets
            c = self.per_ticket.get(coarse)
            if c is None:
                self.per_ticket[coarse] = _Ema(per, last_s=per)
                self._pt_new += 1
            else:
                c.update(per)

    def observe_many(self, query_fp, policy: ExecutionPolicy, sig, bucket: int,
                     wave_s: float, tickets: int, *, shard: bool) -> None:
        pol_fp = policy.fingerprint()
        shard_token = policy.shard_token() if shard else ()
        self._observe(
            ("many", query_fp, pol_fp, sig, shard_token, bucket), wave_s,
            coarse=("many", query_fp, pol_fp), tickets=tickets,
        )

    def observe_serial(self, query_fp, policy: ExecutionPolicy,
                       wave_s: float) -> None:
        pol_fp = policy.fingerprint()
        self._observe(("serial", query_fp, pol_fp), wave_s,
                      coarse=("serial", query_fp, pol_fp), tickets=1)

    def observe_fused(self, member_fps, wave_s: float, tickets: int,
                      meta: dict | None = None) -> None:
        key = _fused_key(member_fps)
        self._observe(key, wave_s, coarse=None, tickets=tickets)
        if meta and not self.suppressed:
            self.measured[key].meta = dict(meta)  # type: ignore[attr-defined]

    # -- static estimates ----------------------------------------------------
    def _plan_for(self, stmt, policy: ExecutionPolicy):
        return self.session._cached_plan(stmt.node, stmt._query_fp, policy)[0]

    def estimate_policy_s(self, stmt, policy: ExecutionPolicy) -> float:
        """Memoized per-call estimate of ``stmt`` under ``policy`` (each
        candidate is estimated on its *own* bound plan — inlining changes
        the tree, which is the whole point of the comparison)."""
        key = ("policy", stmt._query_fp, policy.fingerprint(),
               self.session._catalog_token())
        est = self.estimates.get(key)
        if est is None:
            plan = self._plan_for(stmt, policy)
            est = estimate_plan(plan, self.session.catalog).seconds()
            self.estimates[key] = est
        return est

    # -- decision log --------------------------------------------------------
    def _decide(self, axis: str, choice, why: str, **detail) -> None:
        self.stats["decisions"] += 1
        self.decisions.append({"axis": axis, "choice": choice, "why": why,
                               **detail})

    # -- axis: FROID vs HEKATON policy --------------------------------------
    def _policy_candidates(self, stmt) -> list[tuple]:
        """``[(candidate_policy, fingerprint), ...]`` for ``stmt``, memoized
        per base-policy *instance* (policies are frozen; id is pinned by
        keeping the base in the memo value, so reuse cannot alias)."""
        base = stmt.policy
        hit = self._cand_memo.get(id(base))
        if hit is not None and hit[0] is base:
            return hit[1]
        froid_like = dataclasses.replace(
            base, name=f"{base.name}[froid]", inline_udfs=True,
            udf_mode="python")
        hek_like = dataclasses.replace(
            base, name=f"{base.name}[hekaton]", inline_udfs=False,
            udf_mode="scan")
        out, seen = [], set()
        for c in (base, froid_like, hek_like):
            fp = c.fingerprint()
            if fp not in seen:
                seen.add(fp)
                out.append((c, fp))
        self._cand_memo[id(base)] = (base, out)
        return out

    def choose_policy(self, stmt) -> ExecutionPolicy:
        """The execution policy ``stmt`` should run under right now."""
        base = stmt.policy
        if not base.compile_plan:
            return base
        cat = self.session._catalog_token()
        hit = self._policy_fast.get(id(stmt))
        if (hit is not None and hit[0] is stmt and hit[2] == self._pt_new
                and hit[3] == cat):
            return hit[1]
        chosen, value_dependent = self._choose_policy_slow(stmt, base, cat)
        if not value_dependent:
            self._policy_fast[id(stmt)] = (stmt, chosen, self._pt_new, cat)
        return chosen

    def _choose_policy_slow(self, stmt, base, cat) -> tuple:
        """``(chosen, value_dependent)``; value-dependent verdicts (both
        candidates measured) must be re-evaluated every call because EMA
        updates can flip them."""
        cands = self._policy_candidates(stmt)
        if len(cands) == 1:
            return base, False
        fp0 = stmt._query_fp
        base_fp = base.fingerprint()

        def measured_per_ticket(pol_fp):
            for kind in ("many", "serial"):
                e = self.per_ticket.get((kind, fp0, pol_fp))
                if e is not None:
                    return kind, e.wave_s
            return None, None

        ms = [measured_per_ticket(fp) for _, fp in cands]
        kinds = {k for k, _ in ms if k is not None}
        for kind in ("many", "serial"):
            if kind in kinds and all(
                    k == kind for k, _ in ms if k is not None):
                both = [(c, fp, v) for (c, fp), (k, v) in zip(cands, ms)
                        if k == kind]
                if len(both) >= 2:
                    # measured evidence on a comparable path wins outright
                    best, best_fp, _ = min(both, key=lambda cfv: cfv[2])
                    if best_fp != base_fp:
                        self.stats["policy_reroutes"] += 1
                        self._decide("policy", best.name, "measured",
                                     stmt=_digest(fp0), kind=kind)
                    return best, True
                break
        # estimates decide; an unmeasured alternative is explored only on
        # a clear estimated win (compiles are not free).  The verdict is
        # memoized — estimates are static per catalog version, so the
        # cache-resident path pays the comparison once, not per call.
        vkey = (fp0, base_fp, cat)
        verdict = self._verdicts.get(vkey)
        if verdict is not None:
            return verdict, False
        ests = [(c, fp, self.estimate_policy_s(stmt, c))
                for c, fp in cands]
        inc_est = next(e for _, fp, e in ests if fp == base_fp)
        best, best_fp, best_est = min(ests, key=lambda cfe: cfe[2])
        chosen = base
        if best_fp != base_fp and best_est < inc_est * EXPLORE_MARGIN:
            self.stats["policy_reroutes"] += 1
            self._decide("policy", best.name, "estimate", stmt=_digest(fp0),
                         est_s=best_est, incumbent_s=inc_est)
            chosen = best
        self._verdicts[vkey] = chosen
        return chosen, False

    # -- axis: batch bucket --------------------------------------------------
    def choose_bucket(self, stmt, sig, k: int, natural: int, cap: int,
                      *, shard: bool) -> int:
        """Bucket for ``k`` same-signature tickets: the natural power-of-
        two bucket, or a larger already-measured one when riding it is
        estimated cheaper than cold-compiling the natural bucket."""
        pol = stmt.policy
        pol_fp = pol.fingerprint()
        shard_token = pol.shard_token() if shard else ()
        prefix = ("many", stmt._query_fp, pol_fp, sig, shard_token)
        warm = self._warm_many.get(prefix)
        if not warm or natural in warm:
            return natural
        rides = {b: e for b, e in warm.items() if natural < b <= cap}
        if not rides:
            return natural
        plan = self._plan_for(stmt, pol)
        devices = pol.shard_devices() if shard else 1
        cold_s = (estimate_compile_s(plan)
                  + estimate_statement_s(plan, self.session.catalog,
                                         bucket=natural, devices=devices))
        ride_bucket, ride_ema = min(rides.items(),
                                    key=lambda be: be[1].wave_s)
        if ride_ema.wave_s < cold_s:
            self.stats["bucket_rides"] += 1
            self._decide("bucket", ride_bucket, "ride-warm",
                         stmt=_digest(stmt._query_fp), natural=natural,
                         warm_wave_s=ride_ema.wave_s, cold_est_s=cold_s)
            return ride_bucket
        return natural

    # -- axis: fuse or not ---------------------------------------------------
    def choose_fuse(self, wave) -> bool:
        """``wave`` is ``[(stmt, n_tickets), ...]`` for one mixed drain.
        Returns whether to run it as one fused program.  Exploration:
        fused first (the static default), per-statement once the fused arm
        is measured but the unfused arm is not; after both, cheaper wins."""
        fused_key = _fused_key(s._query_fp for s, _ in wave)
        fused = self.measured.get(fused_key)
        if fused is None:
            self._decide("fuse", True, "explore-fused",
                         wave=_digest(fused_key[1]))
            self.stats["waves_fused"] += 1
            return True
        unfused_s, have_all = 0.0, True
        for stmt, n in wave:
            # parameter-free members run the serial path inside an unfused
            # drain, so their per-ticket evidence lands under "serial"
            e = next((self.per_ticket[k] for k in (
                ("many", stmt._query_fp, stmt.policy.fingerprint()),
                ("serial", stmt._query_fp, stmt.policy.fingerprint()),
            ) if k in self.per_ticket), None)
            if e is None:
                have_all = False
                break
            unfused_s += e.wave_s * n
        if not have_all:
            self._decide("fuse", False, "explore-unfused",
                         wave=_digest(fused_key[1]))
            self.stats["waves_unfused"] += 1
            return False
        prev = self._fuse_last.get(fused_key)
        if prev is None:
            take_fused = fused.wave_s <= unfused_s
        elif prev:
            # sticky: leave the fused incumbent only on a clear unfused win
            take_fused = not (unfused_s < fused.wave_s * FUSE_MARGIN)
        else:
            take_fused = fused.wave_s < unfused_s * FUSE_MARGIN
        self._fuse_last[fused_key] = take_fused
        self._decide("fuse", take_fused, "measured",
                     wave=_digest(fused_key[1]), fused_s=fused.wave_s,
                     unfused_s=unfused_s)
        self.stats["waves_fused" if take_fused else "waves_unfused"] += 1
        return take_fused

    # -- persistence ---------------------------------------------------------
    def export_state(self) -> dict:
        """JSON-safe snapshot of the measured cost model for the persistent
        tier (``repro/persist/costs.py``).  Fault-window samples were
        already excluded at intake — :meth:`suppress` drops them before
        they can reach ``measured``/``per_ticket`` — so a save can never
        leak degraded-wave costs into a fresh worker's warm start."""

        def rows(table):
            out = []
            for key, ema in table.items():
                meta = getattr(ema, "meta", None)
                if meta is not None:
                    try:
                        json.dumps(meta)
                    except (TypeError, ValueError):
                        meta = None
                out.append([repr(key), ema.wave_s, ema.n, ema.last_s, meta])
            return out

        return {"measured": rows(self.measured),
                "per_ticket": rows(self.per_ticket)}

    def import_state(self, state: dict, *, replace: bool = False) -> int:
        """Warm-start the measured model from :meth:`export_state` output.

        Locally-observed evidence wins over imported records unless
        ``replace`` (a live EMA reflects *this* process's actual costs).
        Returns the number of records adopted.  Malformed rows are skipped
        — a cost table can only ever steer routing, never break results.
        """
        from repro.persist.keys import parse_key

        adopted = 0
        for attr in ("measured", "per_ticket"):
            table = getattr(self, attr)
            for row in state.get(attr, ()):
                try:
                    key = parse_key(row[0])
                    wave_s, n, last_s = float(row[1]), int(row[2]), float(row[3])
                except (ValueError, SyntaxError, TypeError, IndexError):
                    continue
                if not replace and key in table:
                    continue
                ema = _Ema(wave_s, n=n, last_s=last_s)
                meta = row[4] if len(row) > 4 else None
                if meta and attr == "measured":
                    ema.meta = dict(meta)  # type: ignore[attr-defined]
                table[key] = ema
                adopted += 1
                if attr == "measured" and key and key[0] == "many":
                    self._warm_many.setdefault(key[:-1], {})[key[-1]] = ema
                elif attr == "per_ticket":
                    # imported coarse evidence can change a policy verdict,
                    # exactly like a freshly-observed key would
                    self._pt_new += 1
        return adopted

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> dict:
        """``Session.cost_stats`` payload: counters, measured configs (keys
        digested for printability), and the recent decision log."""
        measured = {}
        for key, ema in self.measured.items():
            kind = key[0]
            label = f"{kind}:{_digest(key[1])}"
            if kind == "many":
                label += f":b{key[-1]}" + (":sharded" if key[4] else "")
            rec = {"wave_s": ema.wave_s, "last_s": ema.last_s, "n": ema.n}
            meta = getattr(ema, "meta", None)
            if meta:
                rec["meta"] = meta
            measured[label] = rec
        return {
            "enabled": True,
            **self.stats,  # "decisions" stays the cumulative counter
            "measured": measured,
            "decision_log": list(self.decisions),
        }


__all__ = ["CostRouter", "EMA_ALPHA", "EXPLORE_MARGIN"]
