"""Model assembly: decoder / encoder / encoder-decoder stacks over
heterogeneous super-blocks (attention incl. GQA/SWA/MLA, Mamba-2, MoE,
cross-attention), with three entry points per model:

  * ``loss_fn(params, batch)``       — next-token CE (chunked, no (B,S,V))
  * ``prefill(params, tokens, …)``   — forward + KV/SSM cache construction
  * ``decode_step(params, cache, t)``— single-token serve step

Depth is folded into ``lax.scan`` over ``n_repeats`` stacked super-blocks
(HLO size stays O(super-block) for 100-layer models); each super-block body
is rematerialized (``jax.checkpoint``) for training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.activations import shard_batch
from repro.models import attention as ATT
from repro.models import ssm as SSM
from repro.models.config import ArchConfig, LayerSpec
from repro.models.layers import (
    COMPUTE_DTYPE,
    _dense_init,
    chunked_softmax_xent,
    init_mlp,
    init_moe,
    init_rmsnorm,
    mlp,
    moe,
    rmsnorm,
)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, spec: LayerSpec, cfg: ArchConfig):
    out = {}
    ks = iter(jax.random.split(key, 8))
    if spec.mixer == "attn":
        out["norm1"] = init_rmsnorm(cfg.d_model)
        out["attn"] = (
            ATT.init_mla(next(ks), cfg) if cfg.mla else ATT.init_attention(next(ks), cfg)
        )
    elif spec.mixer == "cross":
        out["norm1"] = init_rmsnorm(cfg.d_model)
        out["attn"] = ATT.init_cross_attention(next(ks), cfg)
    elif spec.mixer == "mamba":
        out["norm1"] = init_rmsnorm(cfg.d_model)
        out["mamba"] = SSM.init_mamba(next(ks), cfg)
    if getattr(spec, "cross_memory", False):
        out["norm_x"] = init_rmsnorm(cfg.d_model)
        out["xattn"] = ATT.init_cross_attention(next(ks), cfg)
    if spec.mlp == "dense":
        out["norm2"] = init_rmsnorm(cfg.d_model)
        out["mlp"] = init_mlp(next(ks), cfg.d_model, cfg.d_ff)
    elif spec.mlp == "moe":
        out["norm2"] = init_rmsnorm(cfg.d_model)
        fe = cfg.moe.d_ff_expert or cfg.d_ff
        out["moe"] = init_moe(next(ks), cfg.d_model, fe, cfg.moe.n_experts,
                              cfg.moe.storage_experts)
    return out


def init_params(key, cfg: ArchConfig):
    k_embed, k_blocks, k_head, k_enc = jax.random.split(key, 4)
    params = {
        "embed": _dense_init(k_embed, (cfg.vocab, cfg.d_model), scale=0.02),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(k_head, (cfg.d_model, cfg.vocab))

    def one_repeat(k):
        lkeys = jax.random.split(k, len(cfg.super_block))
        return {
            f"layer{i}": _init_layer(lk, spec, cfg)
            for i, (lk, spec) in enumerate(zip(lkeys, cfg.super_block))
        }

    rkeys = jax.random.split(k_blocks, cfg.n_repeats)
    per = [one_repeat(k) for k in rkeys]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    if cfg.n_encoder_layers:
        enc_spec = LayerSpec(mixer="attn", mlp="dense")
        ekeys = jax.random.split(k_enc, cfg.n_encoder_layers)
        eper = [
            {"layer0": _init_layer(k, enc_spec, cfg)} for k in ekeys
        ]
        params["encoder"] = {
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *eper),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# sequence-form stack (train / prefill)
# ---------------------------------------------------------------------------


def _layer_seq(lp, spec: LayerSpec, x, cfg: ArchConfig, memory, q_offset,
               causal=True):
    cache_out = {}
    if spec.mixer == "attn":
        h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        if cfg.mla:
            o, latent = ATT.mla_seq(lp["attn"], h, cfg, q_offset=q_offset)
            cache_out["latent"] = latent
        else:
            o, kv = ATT.attention_seq(
                lp["attn"], h, cfg, window=spec.window, q_offset=q_offset,
                causal=causal,
            )
            cache_out["kv"] = kv
        x = x + o
    elif spec.mixer == "cross":
        h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        mkv = ATT.cross_memory(lp["attn"], memory, cfg)
        x = x + ATT.cross_attention(lp["attn"], h, mkv, cfg)
        cache_out["memory_kv"] = mkv
    elif spec.mixer == "mamba":
        h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        o, state = SSM.mamba_seq(lp["mamba"], h, cfg)
        cache_out["ssm"] = state
        x = x + o
    if getattr(spec, "cross_memory", False):
        h = rmsnorm(x, lp["norm_x"], cfg.norm_eps)
        mkv = ATT.cross_memory(lp["xattn"], memory, cfg)
        x = x + ATT.cross_attention(lp["xattn"], h, mkv, cfg)
        cache_out["memory_kv"] = mkv
    if spec.mlp == "dense":
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["norm2"], cfg.norm_eps))
    elif spec.mlp == "moe":
        x = x + moe(lp["moe"], rmsnorm(x, lp["norm2"], cfg.norm_eps),
                    cfg.moe.top_k)
    return x, cache_out


def _stack_seq(blocks, x, cfg: ArchConfig, memory, q_offset, *,
               collect_cache=False, remat=True, causal=True,
               super_block=None):
    super_block = super_block or cfg.super_block

    def body(carry, bp):
        h = shard_batch(carry)
        caches = {}
        for i, spec in enumerate(super_block):
            h, c = _layer_seq(bp[f"layer{i}"], spec, h, cfg, memory, q_offset,
                              causal)
            caches[f"layer{i}"] = c
        return shard_batch(h), (caches if collect_cache else 0)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, blocks)
    return x, caches


def forward(params, tokens, cfg: ArchConfig, memory=None, *, remat=True):
    """Token ids -> final hidden states (B, S, D) in COMPUTE_DTYPE."""
    x = shard_batch(params["embed"].astype(COMPUTE_DTYPE)[tokens])
    if cfg.n_encoder_layers and memory is not None:
        memory = encode(params, memory, cfg, remat=remat)
    if memory is not None:
        memory = memory.astype(COMPUTE_DTYPE)
    x, _ = _stack_seq(params["blocks"], x, cfg, memory, 0, remat=remat)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def encode(params, frames, cfg: ArchConfig, *, remat=True):
    """Encoder stack over stub frontend embeddings (B, S_enc, D)."""
    enc = params["encoder"]
    x = frames.astype(COMPUTE_DTYPE)
    spec = (LayerSpec(mixer="attn", mlp="dense"),)
    x, _ = _stack_seq(enc["blocks"], x, cfg, None, 0, remat=remat,
                      causal=False, super_block=spec)
    return rmsnorm(x, enc["final_norm"], cfg.norm_eps)


def lm_head(params, x, cfg: ArchConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum(
        "bsd,dv->bsv", x.astype(jnp.float32), w.astype(jnp.float32)
    )


def loss_fn(params, batch, cfg: ArchConfig, *, remat=True):
    """batch: {tokens (B,S), labels (B,S)[, memory (B,M,D)]}"""
    x = forward(params, batch["tokens"], cfg, batch.get("memory"), remat=remat)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    mask = batch.get("mask")
    return chunked_softmax_xent(x, w, batch["labels"], mask)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def _cache_len(spec: LayerSpec, max_len: int) -> int:
    if spec.mixer == "attn" and spec.window is not None:
        return min(spec.window, max_len)
    return max_len


def init_cache(cfg: ArchConfig, batch: int, max_len: int, memory_len: int = 0,
               dtype=COMPUTE_DTYPE):
    """Zero-initialized decoding cache pytree (stacked per super-block)."""
    R = cfg.n_repeats
    cache = {"pos": jnp.zeros((), jnp.int32)}
    layers = {}
    for i, spec in enumerate(cfg.super_block):
        c = {}
        if spec.mixer == "attn":
            if cfg.mla:
                m = cfg.mla
                c["latent"] = jnp.zeros(
                    (R, batch, max_len, m.kv_lora_rank + m.qk_rope_head_dim), dtype
                )
            else:
                L = _cache_len(spec, max_len)
                kv_shape = (R, batch, cfg.n_kv_heads, L, cfg.head_dim)
                if cfg.kv_cache_int8:
                    # int8 codes + per-(token, head) f32 scales (§Perf)
                    c["kv"] = (
                        jnp.zeros(kv_shape, jnp.int8),
                        jnp.ones(kv_shape[:-1], jnp.float32),
                        jnp.zeros(kv_shape, jnp.int8),
                        jnp.ones(kv_shape[:-1], jnp.float32),
                    )
                else:
                    c["kv"] = (
                        jnp.zeros(kv_shape, dtype),
                        jnp.zeros(kv_shape, dtype),
                    )
        elif spec.mixer == "mamba":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            H = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.state_dim
            c["ssm"] = (
                jnp.zeros((R, batch, s.conv_kernel - 1, conv_dim), dtype),
                jnp.zeros((R, batch, H, s.state_dim, s.head_dim), jnp.float32),
            )
        if spec.mixer == "cross" or getattr(spec, "cross_memory", False):
            c["memory_kv"] = (
                jnp.zeros(
                    (R, batch, cfg.n_kv_heads, memory_len, cfg.head_dim), dtype
                ),
                jnp.zeros(
                    (R, batch, cfg.n_kv_heads, memory_len, cfg.head_dim), dtype
                ),
            )
        layers[f"layer{i}"] = c
    cache["layers"] = layers
    return cache


def prefill(params, tokens, cfg: ArchConfig, memory=None, max_len=None,
            *, remat=False):
    """Forward over the prompt; returns (last-token logits, cache)."""
    B, S = tokens.shape
    max_len = max_len or cfg.max_seq_len
    x = shard_batch(params["embed"].astype(COMPUTE_DTYPE)[tokens])
    if cfg.n_encoder_layers and memory is not None:
        memory = encode(params, memory, cfg, remat=remat)
    if memory is not None:
        memory = memory.astype(COMPUTE_DTYPE)
    x, caches = _stack_seq(
        params["blocks"], x, cfg, memory, 0, collect_cache=True, remat=remat
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(params, x[:, -1:], cfg)[:, 0]

    # assemble fixed-size decode cache from prefill products
    cache = init_cache(cfg, B, max_len,
                       memory.shape[1] if memory is not None else 0)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    for i, spec in enumerate(cfg.super_block):
        src = caches[f"layer{i}"]
        dst = cache["layers"][f"layer{i}"]
        if "kv" in dst:
            L = dst["kv"][0].shape[3]
            k, v = src["kv"]  # (R, B, Hkv, S, hd)
            take = min(S, L)

            def place(buf, arr):
                upd = jax.lax.dynamic_update_slice_in_dim(
                    buf, arr[:, :, :, S - take : S].astype(buf.dtype), 0, axis=3
                )
                if spec.window is not None:
                    # ring alignment: key for absolute pos p sits at p % L
                    upd = jnp.roll(upd, (S - take) % L, axis=3)
                return upd

            if cfg.kv_cache_int8:
                kq, ks = ATT.quantize_kv(k)
                vq, vs = ATT.quantize_kv(v)
                dst["kv"] = (
                    place(dst["kv"][0], kq), place(dst["kv"][1], ks),
                    place(dst["kv"][2], vq), place(dst["kv"][3], vs),
                )
            else:
                dst["kv"] = (place(dst["kv"][0], k), place(dst["kv"][1], v))
        if "latent" in dst:
            dst["latent"] = jax.lax.dynamic_update_slice_in_dim(
                dst["latent"], src["latent"], 0, axis=2
            )
        if "ssm" in dst:
            conv, ssd = src["ssm"]
            dst["ssm"] = (conv.astype(dst["ssm"][0].dtype), ssd)
        if "memory_kv" in dst and "memory_kv" in src:
            dst["memory_kv"] = src["memory_kv"]
    return logits, cache


def decode_step(params, cache, tokens, cfg: ArchConfig):
    """One serve step: tokens (B, 1) + cache -> (logits (B, V), cache')."""
    pos = cache["pos"]
    x = shard_batch(params["embed"].astype(COMPUTE_DTYPE)[tokens])

    def body(carry, xs):
        h = carry
        bp, lc = xs
        new_lc = {}
        for i, spec in enumerate(cfg.super_block):
            lp = bp[f"layer{i}"]
            c = lc[f"layer{i}"]
            nc = {}
            if spec.mixer == "attn":
                hh = rmsnorm(h, lp["norm1"], cfg.norm_eps)
                if cfg.mla:
                    o, latent = ATT.mla_decode(lp["attn"], hh, c["latent"], pos, cfg)
                    nc["latent"] = latent
                else:
                    o, kv = ATT.attention_decode(
                        lp["attn"], hh, c["kv"], pos, cfg, window=spec.window
                    )
                    nc["kv"] = kv
                h = h + o
            elif spec.mixer == "cross":
                hh = rmsnorm(h, lp["norm1"], cfg.norm_eps)
                h = h + ATT.cross_attention(lp["attn"], hh, c["memory_kv"], cfg)
                nc["memory_kv"] = c["memory_kv"]
            elif spec.mixer == "mamba":
                hh = rmsnorm(h, lp["norm1"], cfg.norm_eps)
                o, st = SSM.mamba_decode(lp["mamba"], hh, c["ssm"], cfg)
                nc["ssm"] = st
                h = h + o
            if getattr(spec, "cross_memory", False):
                hh = rmsnorm(h, lp["norm_x"], cfg.norm_eps)
                h = h + ATT.cross_attention(lp["xattn"], hh, c["memory_kv"], cfg)
                nc["memory_kv"] = c["memory_kv"]
            if spec.mlp == "dense":
                h = h + mlp(lp["mlp"], rmsnorm(h, lp["norm2"], cfg.norm_eps))
            elif spec.mlp == "moe":
                h = h + moe(lp["moe"], rmsnorm(h, lp["norm2"], cfg.norm_eps),
                            cfg.moe.top_k)
            new_lc[f"layer{i}"] = nc
        return h, new_lc

    x, new_layers = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(params, x, cfg)[:, 0]
    return logits, {"pos": pos + 1, "layers": new_layers}
