"""Resilience layer: degradation ladder, circuit breakers, deadlines,
and the deterministic fault-injection harness.

Public surface re-exported here; see each module's docstring for the
design. ``ladder`` drains scheduler waves down the tier stack
(fused → many → serial → interp), ``breaker`` gates persistently
failing (statement, tier) pairs, ``faults`` supplies the typed error
taxonomy plus the :class:`FaultInjector` seam hook that chaos tests
install into a :class:`~repro.core.session.Session`.
"""
from repro.resilience.breaker import (
    CLOSED,
    HALF_OPEN,
    OPEN,
    BreakerBoard,
    BreakerConfig,
    CircuitBreaker,
)
from repro.resilience.faults import (
    SITES,
    DeadlineExceeded,
    FaultInjector,
    FaultSpec,
    InjectedFault,
    ResilienceError,
    WaveResultMismatch,
)
from repro.resilience.ladder import (
    TIERS,
    UNSET,
    DegradationLadder,
    ResilienceConfig,
    RetryPolicy,
    WaveGroup,
    WorkItem,
)

__all__ = [
    "SITES",
    "TIERS",
    "UNSET",
    "ResilienceError",
    "InjectedFault",
    "DeadlineExceeded",
    "WaveResultMismatch",
    "FaultSpec",
    "FaultInjector",
    "BreakerConfig",
    "CircuitBreaker",
    "BreakerBoard",
    "CLOSED",
    "OPEN",
    "HALF_OPEN",
    "RetryPolicy",
    "ResilienceConfig",
    "WorkItem",
    "WaveGroup",
    "DegradationLadder",
]
