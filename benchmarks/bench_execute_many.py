"""Batched invocation: serial `execute` loop vs `execute_many` vs async
pipelining, swept over the number of same-signature parameter sets.

This is the engine-level analogue of the paper's set-oriented argument one
level up: a prepared statement invoked N times serially pays N dispatches
and N device syncs, while `execute_many` stacks the N parameter sets into
one vmapped device program (tables broadcast) and pays one of each.

    PYTHONPATH=src python -m benchmarks.bench_execute_many [--quick]

Rows:
    execmany/serial/N       — N sequential stmt.execute calls
    execmany/batched/N      — one stmt.execute_many over the same N sets
    execmany/async/N        — N execute_async dispatches, then N syncs
speedup in `derived` is serial/batched wall time; results are asserted
element-wise identical before timing.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    FROID,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)

M_ROWS = 20_000
N_ROWS = 2_000
M_ROWS_QUICK = 5_000
N_ROWS_QUICK = 500
# quick mode keeps the full sweep — the CI gate reads the N=1024 row
SWEEP = (1, 32, 1024)


def _setup(quick: bool) -> Session:
    m = M_ROWS_QUICK if quick else M_ROWS
    n = N_ROWS_QUICK if quick else N_ROWS
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 400, m),
        d_val=rng.uniform(0, 100, m).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 400, n))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())
    return db


def _q():
    return (
        scan("T")
        .filter(col("a") < param("cutoff"))
        .compute(v=udf("key_total", col("a")))
        .project("v")
    )


def _check_identical(serial, batched):
    for s, b in zip(serial, batched):
        np.testing.assert_array_equal(
            np.asarray(s.masked.mask), np.asarray(b.masked.mask)
        )
        np.testing.assert_allclose(
            np.asarray(s.masked.table.columns["v"].data),
            np.asarray(b.masked.table.columns["v"].data),
            rtol=1e-5,
        )


def run(quick: bool = False):
    db = _setup(quick)
    stmt = db.prepare(_q(), FROID)
    rng = np.random.default_rng(7)
    stmt.execute(params={"cutoff": 1})  # pay the unbatched jit once

    # the serial arm at N=1024 is the slow quadrant (that's the point), so
    # each arm is timed in one representative warm pass; the timed passes
    # double as the element-wise identity check between the two arms
    for n in SWEEP:
        params_list = [{"cutoff": int(c)} for c in rng.integers(1, 400, n)]

        t0 = time.perf_counter()
        serial_r = [stmt.execute(params=p) for p in params_list]
        t_serial = time.perf_counter() - t0
        emit(f"execmany/serial/{n}", t_serial / n * 1e6,
             f"{n} dispatch+sync round trips")

        stmt.execute_many(params_list)  # pay the per-bucket vmapped jit
        t0 = time.perf_counter()
        batched_r = stmt.execute_many(params_list)
        t_batched = time.perf_counter() - t0
        st = batched_r[0].stats
        emit(f"execmany/batched/{n}", t_batched / n * 1e6,
             f"speedup={t_serial / t_batched:.1f}x "
             f"bucket={st.get('batch_bucket')} "
             f"dispatch_us={st.get('dispatch_s', 0) * 1e6:.0f}")
        _check_identical(serial_r, batched_r)

        # async pipeline: dispatch all, then sync all — overlaps host
        # dispatch of call i+1 with device compute of call i
        t0 = time.perf_counter()
        futures = [stmt.execute_async(params=p) for p in params_list]
        for f in futures:
            f.result().masked
        t_async = time.perf_counter() - t0
        emit(f"execmany/async/{n}", t_async / n * 1e6,
             f"vs serial {t_serial / t_async:.1f}x")


if __name__ == "__main__":
    run()
