"""Relational algebra plan nodes.

Execution is mask-based ("selection vectors"): filters never compact rows,
they AND into a row mask — the TPU adaptation that keeps every operator
static-shaped and therefore jit/pjit-compilable.  The Apply operator
(Galindo-Legaria & Joshi; paper §3.2) is a first-class node:

    R  A⊗  E  =  ⋃_{r∈R} ({r} ⊗ E(r))

with join types cross / outer / semi / anti, plus the probe/pass-through
variant used for early RETURNs (paper §4.2.1).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Sequence

from repro.core import scalar as S

_ids = itertools.count()


class RelNode:
    """Base plan node."""

    def __init__(self):
        self.node_id = next(_ids)

    def children(self) -> list["RelNode"]:
        return []

    def with_children(self, kids: list["RelNode"]) -> "RelNode":
        assert not kids
        return self

    def exprs(self) -> list[S.Scalar]:
        return []


class Scan(RelNode):
    """Scan of a named base table in the catalog."""

    def __init__(self, table: str, alias: str | None = None):
        super().__init__()
        self.table = table
        self.alias = alias or table

    def __repr__(self):
        return f"Scan({self.table})"


class ConstantScan(RelNode):
    """One row, no columns (paper §4.2.1)."""

    def __repr__(self):
        return "ConstantScan"


class Compute(RelNode):
    """ComputeScalar: add/overwrite computed columns on each row."""

    def __init__(self, child: RelNode, exprs: dict[str, S.Scalar]):
        super().__init__()
        self.child = child
        self.computed = {k: S.wrap(v) for k, v in exprs.items()}

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Compute(kids[0], self.computed)

    def exprs(self):
        return list(self.computed.values())

    def __repr__(self):
        return f"Compute({self.child!r}, {list(self.computed)})"


class Project(RelNode):
    """Keep only ``cols`` (optionally renaming via ``{new: old}``)."""

    def __init__(self, child: RelNode, cols: Sequence[str] | dict[str, str]):
        super().__init__()
        self.child = child
        if isinstance(cols, dict):
            self.cols = dict(cols)
        else:
            self.cols = {c: c for c in cols}

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Project(kids[0], self.cols)

    def __repr__(self):
        return f"Project({self.child!r}, {list(self.cols)})"


class Filter(RelNode):
    def __init__(self, child: RelNode, pred: S.Scalar):
        super().__init__()
        self.child = child
        self.pred = S.wrap(pred)

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Filter(kids[0], self.pred)

    def exprs(self):
        return [self.pred]

    def __repr__(self):
        return f"Filter({self.child!r}, {self.pred!r})"


class Join(RelNode):
    """Equi-join on key column pairs.  ``kind`` in inner|left|semi|anti.

    The build (right) side must be key-unique for inner/left joins — the
    engine verifies this at execution.  Lowered to a dense-key gather when
    the build keys form a dense integer range (FK join), else to
    sort + searchsorted (sort-merge; TPU-friendly, no hash tables).
    """

    def __init__(
        self,
        left: RelNode,
        right: RelNode,
        on: Sequence[tuple[str, str]],
        kind: str = "inner",
    ):
        super().__init__()
        assert kind in ("inner", "left", "semi", "anti"), kind
        self.left, self.right, self.on, self.kind = left, right, list(on), kind

    def children(self):
        return [self.left, self.right]

    def with_children(self, kids):
        return Join(kids[0], kids[1], self.on, self.kind)

    def __repr__(self):
        return f"Join[{self.kind}]({self.left!r}, {self.right!r}, on={self.on})"


class Apply(RelNode):
    """Correlated apply.  ``right`` may contain Outer(col) references to the
    current left row.  kinds: cross | outer | semi | anti.

    probe/pass-through (paper §4.2.1): when ``passthrough`` is set (a scalar
    predicate over left columns), rows where it evaluates TRUE bypass the
    right side entirely (their right-side columns are NULL); used to stop
    evaluation after an early RETURN."""

    def __init__(
        self,
        left: RelNode,
        right: RelNode,
        kind: str = "outer",
        passthrough: S.Scalar | None = None,
    ):
        super().__init__()
        assert kind in ("cross", "outer", "semi", "anti"), kind
        self.left, self.right, self.kind = left, right, kind
        self.passthrough = passthrough

    def children(self):
        return [self.left, self.right]

    def with_children(self, kids):
        return Apply(kids[0], kids[1], self.kind, self.passthrough)

    def exprs(self):
        return [self.passthrough] if self.passthrough is not None else []

    def __repr__(self):
        return f"Apply[{self.kind}]({self.left!r}, {self.right!r})"


@dataclasses.dataclass
class AggSpec:
    fn: str  # sum | count | count_star | min | max | avg
    expr: S.Scalar | None  # None for count_star


class GroupAgg(RelNode):
    """Grouped aggregation.  keys == [] is a full-table aggregate (1 row).

    ``capacity``: static upper bound on group count for jit paths; the
    eager executor computes exact groups host-side when unset."""

    def __init__(
        self,
        child: RelNode,
        keys: Sequence[str],
        aggs: dict[str, AggSpec | tuple],
        capacity: int | None = None,
        dense_range: tuple[int, int] | None = None,
    ):
        super().__init__()
        self.child = child
        self.keys = list(keys)
        self.aggs: dict[str, AggSpec] = {}
        for name, spec in aggs.items():
            if isinstance(spec, tuple):
                fn, expr = spec
                spec = AggSpec(fn, None if expr is None else S.wrap(expr))
            self.aggs[name] = spec
        self.capacity = capacity
        # stats-derived: key values densely cover [lo, hi] -> the executor
        # uses direct gid = key - lo segmenting (no sort)
        self.dense_range = dense_range

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return GroupAgg(kids[0], self.keys, dict(self.aggs), self.capacity,
                        self.dense_range)

    def exprs(self):
        return [a.expr for a in self.aggs.values() if a.expr is not None]

    def __repr__(self):
        return f"GroupAgg({self.child!r}, keys={self.keys}, aggs={list(self.aggs)})"


class Sort(RelNode):
    def __init__(
        self,
        child: RelNode,
        keys: Sequence[tuple[str, bool]],  # (col, ascending)
        limit: int | None = None,
    ):
        super().__init__()
        self.child = child
        self.keys = list(keys)
        self.limit = limit

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return Sort(kids[0], self.keys, self.limit)

    def __repr__(self):
        return f"Sort({self.child!r}, {self.keys}, limit={self.limit})"


class LoopScan(RelNode):
    """A rewritten cursor loop (Aggify): fold the child relation's rows, in
    order, into a single-row output — the relational operator the loop
    rewrite pass (:mod:`repro.loops.rewrite`) produces.

    ``carry`` maps state names to their loop-entry init expressions
    (evaluated once per execution, referencing only Outer/Param/Const).
    Two lowerings, chosen by ``kind``:

    * ``"scan"``: ``steps`` is an ordered list of ``(name, expr)`` updates
      evaluated per row under ``lax.scan``; exprs reference carried state
      via ``Var(name)`` and the current cursor row via ``ColRef(col)``.
      The reserved carried flag ``__done`` (sticky loop exit: BREAK or a
      failed guard) and the per-row ``__live`` pseudo-variable implement
      predicated early exit.
    * ``"reduce"``: the fold is commutative — ``reductions`` maps each
      output to ``(mode, op_or_col, term, pred)``: ``("fold", "+"|"*",
      term, pred|None)`` lowers to a masked ``sum``/``prod`` over the
      relation, ``("last", col, None, None)`` to a last-active-row gather
      (the final fetch-variable value).

    Output: one row, columns ``outputs`` (the loop's live-out variables).
    Attribute order keeps the child first — fingerprinting (``_norm``) and
    rewrites rely on children-before-exprs ordering."""

    def __init__(
        self,
        child: RelNode,
        carry: dict[str, S.Scalar],
        steps: Sequence[tuple[str, S.Scalar]],
        kind: str = "scan",
        reductions: dict[str, tuple] | None = None,
        outputs: Sequence[str] = (),
    ):
        super().__init__()
        assert kind in ("scan", "reduce"), kind
        self.child = child
        self.carry = {k: S.wrap(v) for k, v in carry.items()}
        self.steps = [(n, S.wrap(e)) for n, e in steps]
        self.kind = kind
        self.reductions = dict(reductions or {})
        self.outputs = list(outputs)

    def children(self):
        return [self.child]

    def with_children(self, kids):
        return LoopScan(kids[0], self.carry, self.steps, self.kind,
                        self.reductions, self.outputs)

    def exprs(self):
        out = list(self.carry.values()) + [e for _, e in self.steps]
        for mode, _, term, pred in self.reductions.values():
            if term is not None:
                out.append(term)
            if pred is not None:
                out.append(pred)
        return out

    def map_exprs(self, fn) -> "LoopScan":
        """Rebuild with every scalar expression passed through ``fn`` — the
        generic hook plan-rewriters (binder substitution, optimizer
        expression passes) use instead of per-node cases."""
        carry = {k: fn(v) for k, v in self.carry.items()}
        steps = [(n, fn(e)) for n, e in self.steps]
        reds = {
            k: (mode, op,
                None if term is None else fn(term),
                None if pred is None else fn(pred))
            for k, (mode, op, term, pred) in self.reductions.items()
        }
        return LoopScan(self.child, carry, steps, self.kind, reds,
                        self.outputs)

    def __repr__(self):
        return (f"LoopScan[{self.kind}]({self.child!r}, "
                f"outputs={self.outputs})")


# ---------------------------------------------------------------------------
# Traversal / rewrite helpers
# ---------------------------------------------------------------------------


def walk_plan(node: RelNode):
    yield node
    for c in node.children():
        yield from walk_plan(c)


def embedded_plans(node: RelNode):
    """The relational plans embedded in ``node``'s own scalar expressions
    (``ScalarSubquery`` / ``Exists``), each yielded once.  The scalar
    traversal stays shallow — plans nested *inside* an embedded plan are
    that plan's business; recurse at the plan level (as
    :func:`walk_plan_deep` does) to reach them.  The single source of
    truth for expression→plan descent: the merge pass's marking and the
    session's occurrence planning both reuse it, so candidate discovery
    and answering can never disagree on what counts as an embedded plan."""
    for e in node.exprs():
        stack = [e]
        while stack:
            x = stack.pop()
            if isinstance(x, (S.ScalarSubquery, S.Exists)):
                yield x.plan
            stack.extend(x.children())


def walk_plan_deep(node: RelNode):
    """Like :func:`walk_plan`, but also descends into the relational plans
    embedded in scalar expressions (:func:`embedded_plans`) — the full set
    of plan nodes an execution of ``node`` may run."""
    yield node
    for p in embedded_plans(node):
        yield from walk_plan_deep(p)
    for c in node.children():
        yield from walk_plan_deep(c)


def node_exprs(node: RelNode) -> list[S.Scalar]:
    return node.exprs()


def transform_plan(node: RelNode, fn) -> RelNode:
    """Bottom-up plan rewrite; ``fn(node) -> node|None`` (identity compare)."""
    old = node.children()
    kids = [transform_plan(c, fn) for c in old]
    if any(a is not b for a, b in zip(kids, old)):
        node = node.with_children(kids)
    out = fn(node)
    return node if out is None else out


def plan_size(node: RelNode) -> int:
    """Operator count including scalar expression nodes — the paper's
    'size of algebrized tree' constraint (§7.2)."""
    total = 0
    for n in walk_plan(node):
        total += 1
        for e in n.exprs():
            total += sum(1 for _ in S.walk(e))
        if isinstance(n, Compute):
            for e in n.computed.values():
                for sub in S.walk(e):
                    if isinstance(sub, (S.ScalarSubquery, S.Exists)):
                        total += plan_size(sub.plan)
    return total


def output_columns(node: RelNode, catalog) -> list[str]:
    """Static schema inference (column names only)."""
    if isinstance(node, Scan):
        return list(catalog[node.table].names())
    if isinstance(node, ConstantScan):
        return []
    if isinstance(node, Compute):
        base = output_columns(node.child, catalog)
        return base + [c for c in node.computed if c not in base]
    if isinstance(node, Project):
        return list(node.cols.keys())
    if isinstance(node, Filter):
        return output_columns(node.child, catalog)
    if isinstance(node, Join):
        l = output_columns(node.left, catalog)
        if node.kind in ("semi", "anti"):
            return l
        r = output_columns(node.right, catalog)
        return l + [c for c in r if c not in l]
    if isinstance(node, Apply):
        l = output_columns(node.left, catalog)
        if node.kind in ("semi", "anti"):
            return l
        r = output_columns(node.right, catalog)
        return l + [c for c in r if c not in l]
    if isinstance(node, GroupAgg):
        return list(node.keys) + list(node.aggs.keys())
    if isinstance(node, Sort):
        return output_columns(node.child, catalog)
    if isinstance(node, LoopScan):
        return list(node.outputs)
    raise TypeError(type(node))
