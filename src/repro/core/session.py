"""Session / PreparedStatement: the engine's prepare-once-execute-many API.

The paper's economics (PVLDB 11(4)) come from planning a UDF-bearing query
*once* and running the set-oriented plan many times.  This module is that
lifecycle as an API:

* :class:`Session` owns the catalog + UDF registry and two caches — a
  **plan cache** (bound + optimized plans, keyed by query fingerprint ×
  policy × catalog/registry state) and an **executable cache** (whole-plan
  jitted callables, additionally keyed by the parameter signature).
* :class:`PreparedStatement` is the client handle: ``prepare`` plans and
  binds (cold); ``execute(params=…)`` runs warm off the cached jitted
  callable — changed parameter *values* ride the same executable, only a
  changed parameter *signature* (dtype/shape/string) re-specializes.
* :class:`QueryResult` reports rows lazily plus the plan, explain text,
  public engine stats and whether the call was served from cache.

Cache invalidation is by content: the catalog/registry tokens cover both
``create_table``/``create_function`` and direct ``catalog[...] =`` pokes
(benchmarks do this), so DDL or data replacement re-plans on next use.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import time
import warnings
from collections import OrderedDict, deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import optimizer as O
from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.binder import Binder, InlineConstraints
from repro.core.executor import Executor, MaskedTable
from repro.core.frontend import Q
from repro.core.interpreter import Interpreter
from repro.core.ir import UdfDef
from repro.core.policy import FROID, ExecutionPolicy, resolve_policy
from repro.tables.table import Column, DictEncoding, Table


# ---------------------------------------------------------------------------
# structural fingerprints (cache keys) — canonical home is
# repro.core.fingerprint (below the optimizer in the import graph, so the
# decorrelation pass's shared-build dedup can fingerprint subtrees without a
# cycle); the names stay re-exported here for the original import surface.
# ---------------------------------------------------------------------------

from repro.core.fingerprint import (  # noqa: E402,F401  (re-exports)
    _expr_key,
    _norm,
    const_hole_key,
    liftable_const,
    parametric_fingerprint,
    plan_fingerprint,
)


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------


class QueryResult:
    """Result of one execution.

    ``table`` (compacted host-visible rows) materializes lazily — the
    masked device form is the primary product, so timing loops that only
    touch ``masked`` never pay the host gather.

    Batched/async executions defer even the masked form: they pass
    ``materialize`` instead of ``masked``, and the first ``masked`` access
    slices this call's rows out of the shared device batch (or syncs the
    in-flight dispatch).  Until then the result is a stats-and-plan shell,
    so fan-out paths never pay O(batch) per-result slicing up front.
    """

    def __init__(self, masked: MaskedTable | None, plan: R.RelNode,
                 elapsed_s: float, stats: dict,
                 policy: ExecutionPolicy | None = None,
                 cache_hit: bool = False, materialize=None):
        if masked is None and materialize is None:
            raise ValueError("QueryResult needs masked or materialize")
        self._masked = masked
        self._materialize = materialize
        self.plan = plan
        self.elapsed_s = elapsed_s
        self.stats = stats
        self.policy = policy
        self.cache_hit = cache_hit
        self._table: Table | None = None

    @property
    def masked(self) -> MaskedTable:
        if self._masked is None:
            self._masked = self._materialize()
            self._materialize = None
        return self._masked

    @property
    def table(self) -> Table:
        if self._table is None:
            self._table = self.masked.compact()
        return self._table

    @property
    def explain(self) -> str:
        return O.explain(self.plan)

    def __repr__(self):
        pol = self.policy.name if self.policy else "?"
        return (f"QueryResult(rows={self.masked.num_rows}, policy={pol}, "
                f"cache_hit={self.cache_hit}, elapsed_s={self.elapsed_s:.4f})")


class AsyncResult:
    """Future returned by :meth:`PreparedStatement.execute_async`.

    The device call is already dispatched; ``result()`` blocks until the
    outputs are ready and returns the :class:`QueryResult`.  ``done()``
    polls readiness without blocking, so callers can pipeline host work
    against device compute.

    A truly-async result occupies one of the session's bounded in-flight
    slots (``policy.max_inflight``) until ``result()`` syncs it — the
    backpressure that keeps a runaway producer from queueing unbounded
    device work.  Degraded (synchronous) results never hold a slot.
    """

    def __init__(self, result: QueryResult, marker=None, session=None):
        self._result = result
        self._marker = marker  # a device array from the in-flight dispatch
        self._session = session
        self._released = session is None

    def done(self) -> bool:
        m = self._marker
        if m is None or not hasattr(m, "is_ready"):
            return True
        return m.is_ready()

    def _release(self) -> None:
        if self._released:
            return
        self._released = True
        try:
            self._session._inflight.remove(self)
        except ValueError:
            pass  # already reaped by a later dispatch's admission pass

    def result(self) -> QueryResult:
        _ = self._result.masked  # forces sync + materialization
        self._release()
        return self._result

    def __repr__(self):
        return f"AsyncResult(done={self.done()})"


#: backward-compatible alias — the old Database.run result type
RunResult = QueryResult


# monotonic stamps for cache tokens: attached to catalog/registry objects
# the first time the session sees them, so a *new* object always gets a new
# stamp even if the allocator reuses a dead object's address (id() alone is
# unsafe as a cache key once the old object is garbage)
_stamps = itertools.count(1)


def _stamp(obj) -> int:
    s = getattr(obj, "_session_stamp", None)
    if s is None:
        s = next(_stamps)
        try:
            obj._session_stamp = s
        except AttributeError:  # frozen dataclass
            object.__setattr__(obj, "_session_stamp", s)
    return s


def _table_content_digest(t: Table) -> str:
    """Value digest of one table: per-column name/dtype/shape/vocab plus the
    raw data and validity bytes.  Cached on the table object — the same
    invalidation model as :func:`_stamp` (replace the Table, get a fresh
    digest), but the digest is *content-derived*, so two processes loading
    identical data agree on it.  This is what makes persistent cache keys
    meaningful across workers: a stamp says "some table object #17", a
    digest says "this exact data"."""
    d = getattr(t, "_content_digest", None)
    if d is None:
        h = hashlib.sha1()
        for name, col in sorted(t.columns.items()):
            arr = np.asarray(col.data)
            h.update(repr((name, str(arr.dtype), arr.shape,
                           _vocab(col.dictionary))).encode())
            h.update(arr.tobytes())
            h.update(np.asarray(col.validity()).tobytes())
        d = h.hexdigest()
        try:
            t._content_digest = d
        except AttributeError:
            pass
    return d


def _udf_content_digest(u: UdfDef) -> str:
    """Structural digest of a UDF definition (via :func:`_norm`), cached on
    the object; the registry half of the content-derived env token."""
    d = getattr(u, "_content_digest", None)
    if d is None:
        d = hashlib.sha1(repr(_norm(u)).encode()).hexdigest()
        try:
            u._content_digest = d
        except AttributeError:
            object.__setattr__(u, "_content_digest", d)
    return d


class _BoundedCache(OrderedDict):
    """Insertion-ordered dict evicting the least-recently-used entry past
    ``cap`` — per-tick table reloads would otherwise grow the plan and
    executable caches without bound in long-running serving loops."""

    def __init__(self, cap: int):
        super().__init__()
        self.cap = cap

    def get(self, key, default=None):
        v = super().get(key, default)
        if key in self:
            self.move_to_end(key)
        return v

    def __setitem__(self, key, value):
        super().__setitem__(key, value)
        self.move_to_end(key)
        while len(self) > self.cap:
            self.popitem(last=False)


# ---------------------------------------------------------------------------
# parameter handling
# ---------------------------------------------------------------------------


def _param_value(v) -> S.Value:
    if isinstance(v, S.Value):
        return v
    if isinstance(v, str):
        return S.Value(jnp.asarray(0, jnp.int32), None, DictEncoding([v]))
    if isinstance(v, bool):
        return S.Value(jnp.asarray(v, bool))
    if isinstance(v, (int, np.integer)):
        return S.Value(jnp.asarray(v, jnp.int32))
    if isinstance(v, (float, np.floating)):
        return S.Value(jnp.asarray(v, jnp.float32))
    arr = jnp.asarray(v)
    if arr.dtype == jnp.float64:
        arr = arr.astype(jnp.float32)
    if arr.dtype == jnp.int64:
        arr = arr.astype(jnp.int32)
    return S.Value(arr)


_SIG_DTYPES = {"float64": "float32", "int64": "int32"}


def param_signature(params: dict | None) -> tuple:
    """The shape of a parameter set: names, dtypes, shapes — and for
    strings the value itself (the dictionary is host-side metadata baked
    into the trace).  Value changes within a signature never re-plan.
    Computed host-side: no device arrays are created here (the hot path
    calls this on every execute)."""
    if not params:
        return ()
    out = []
    for name in sorted(params):
        v = params[name]
        if isinstance(v, str):
            out.append((name, "str", v))
        elif isinstance(v, S.Value):
            # the dictionary is baked into the trace as host metadata, so
            # it is part of the signature (same codes, different vocab
            # would otherwise warm-hit the wrong executable)
            out.append((name, str(v.data.dtype), tuple(v.data.shape),
                        _vocab(v.dictionary)))
        elif isinstance(v, bool):
            out.append((name, "bool", ()))
        elif isinstance(v, (int, np.integer)):
            out.append((name, "int32", ()))
        elif isinstance(v, (float, np.floating)):
            out.append((name, "float32", ()))
        elif hasattr(v, "dtype") and hasattr(v, "shape"):
            dt = str(v.dtype)
            out.append((name, _SIG_DTYPES.get(dt, dt), tuple(v.shape)))
        else:
            arr = np.asarray(v)
            dt = str(arr.dtype)
            out.append((name, _SIG_DTYPES.get(dt, dt), tuple(arr.shape)))
    return tuple(out)


def batch_bucket(n: int, max_batch: int) -> int:
    """Device batch size for ``n`` same-signature param sets: the next
    power of two, capped at ``max_batch``.  Bucketing means a statement
    executed at N = 5, 6, 7 … shares one vmapped executable (padded to 8)
    instead of re-specializing per distinct N."""
    if n <= 0:
        raise ValueError("batch of zero parameter sets")
    b = 1
    while b < n:
        b <<= 1
    return max(1, min(b, max_batch))


#: distinct-binding counts at or below this threshold keep exact template
#: pools; above it the pool pads to the next power of two.  Small pools
#: re-jit rarely and padding them is pure waste; large growing binding
#: populations would otherwise re-specialize the fused program once per
#: distinct d (the CSE d-churn bug) — bucketing bounds that to O(log d).
#: Benchmarks monkeypatch this to measure both arms (BENCH_pr8 justifies
#: the cutoff with the padded-pool overhead numbers).
CSE_EXACT_D = 8


def _pool_pad(d: int) -> int:
    """Template-pool slot count for ``d`` distinct bindings: exact at or
    below :data:`CSE_EXACT_D`, the next power of two above it.  Padded
    slots repeat the last real binding and are computed-then-ignored,
    exactly like batch-bucket padding rows — no ticket's slot index ever
    references one."""
    if d <= CSE_EXACT_D:
        return d
    b = 1
    while b < d:
        b <<= 1
    return b


def _stack_params(params_list: list[dict]) -> dict:
    """Stack same-signature param dicts into one batched argument pytree:
    name -> (data (B, …), valid (B, …)).  Scalars take the numpy fast path
    (one host array per name, not B device scalars)."""
    first = params_list[0]
    out = {}
    for name in sorted(first):
        vs = [p[name] for p in params_list]
        v0 = vs[0]
        if isinstance(v0, bool):
            data = jnp.asarray(np.asarray(vs, dtype=bool))
        elif isinstance(v0, (int, np.integer)):
            data = jnp.asarray(np.asarray(vs), jnp.int32)
        elif isinstance(v0, (float, np.floating)):
            data = jnp.asarray(np.asarray(vs), jnp.float32)
        else:
            vals = [_param_value(v) for v in vs]
            out[name] = (
                jnp.stack([v.data for v in vals]),
                jnp.stack([v.validity() for v in vals]),
            )
            continue
        out[name] = (data, jnp.ones((len(vs),), bool))
    return out


def _batched_avals(params0: dict, bucket: int) -> dict:
    """Abstract (shape, dtype) pytree of a :func:`_stack_params` batch of
    ``bucket`` tickets shaped like ``params0`` — what the persistent tier's
    AOT lower runs against, without materializing ``bucket`` param copies.
    Stacking two copies (not one) keeps every leaf's per-ticket trailing
    shape explicit, then the leading axis is rewritten to the bucket."""
    if not params0:
        return {}
    ex = _stack_params([params0, params0])
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((bucket,) + tuple(x.shape[1:]),
                                       x.dtype),
        ex)


def _vocab(dictionary) -> tuple | None:
    """Host tuple of a DictEncoding's contents (shared by the signature
    and binding-key paths)."""
    if dictionary is None:
        return None
    return tuple(dictionary.decode(i) for i in range(len(dictionary)))


def _binding_key(v) -> tuple:
    """Hashable identity of one parameter value — the dedup key of the
    template binding pools (value-level, unlike :func:`param_signature`
    which deliberately erases values for numeric params).  ``S.Value``
    bindings cost a device→host read, so their key is memoized on the
    instance — repeated tickets carrying the same Value object sync
    once, not once per ticket."""
    if isinstance(v, S.Value):
        cached = getattr(v, "_binding_key_cache", None)
        if cached is not None:
            return cached
        arr = np.asarray(v.data)
        valid = None if v.valid is None else np.asarray(v.valid).tobytes()
        key = ("value", str(arr.dtype), arr.shape, arr.tobytes(), valid,
               _vocab(v.dictionary))
        v._binding_key_cache = key
        return key
    if isinstance(v, str):
        return ("str", v)
    if isinstance(v, bool):
        return ("bool", v)
    if isinstance(v, (int, np.integer)):
        return ("int", int(v))
    if isinstance(v, (float, np.floating)):
        # bit-pattern identity at the executed precision: -0.0 must not
        # dedup against 0.0 (sign-sensitive templates would answer with
        # the wrong sign of infinity), and NaN must dedup against itself
        # (value equality would mint a fresh pool slot per NaN ticket)
        return ("float", np.float32(float(v)).tobytes())
    arr = np.asarray(v)
    return ("array", str(arr.dtype), arr.shape, arr.tobytes())


def _maximal_cse_occurrences(merged, plan) -> list:
    """Template occurrences of ``plan`` that actually execute in a member's
    trace: top-down, stopping at the first marked node (a shared-constant
    or template mark) — everything beneath it is answered from a pool and
    never runs, so nested occurrences must not open pool groups of their
    own.  Memoized on the (cached, immutable) FusedPlan per member plan —
    warm drains must not re-walk plans they have already planned."""
    cache = getattr(merged, "_occ_cache", None)
    if cache is None:
        cache = merged._occ_cache = {}
    # entries hold the plan itself, so a hit is identity-verified — an
    # id() recycled onto a different plan object can never match
    hit = cache.get(id(plan))
    if hit is not None and hit[0] is plan:
        return hit[1]
    out = []

    def visit(n):
        nid = n.node_id
        if nid in merged.template_ids:
            out.append(n)
            return
        if nid in merged.shared_ids:
            return  # answered from the constant pool; nothing below runs
        for p in R.embedded_plans(n):
            visit(p)
        for c in n.children():
            visit(c)

    visit(plan)
    cache[id(plan)] = (plan, out)
    return out


def _plan_template_groups(merged, members, params_by_member):
    """Host-side binding planning for a fused wave.

    For every maximal template occurrence of every batched member, group by
    (template fingerprint, binding signature) into a :class:`_PoolGroup`,
    dedup the tickets' hole-value tuples into the group's distinct-binding
    list, and record each ticket's pool slot.  Returns ``(groups,
    member_tmaps, slot_maps, slot_names, template_token)`` where
    ``member_tmaps[i]`` maps occurrence ``node_id -> group index`` for
    member ``i``, ``slot_maps[i]`` maps ``node_id -> [slot per ticket]``,
    ``slot_names[i]`` maps ``node_id -> reserved slot-parameter name``
    (the occurrence's *ordinal* within this walk — deterministic from the
    plan structure, so the fused program's argument pytree spells
    identically in every process and AOT-compiled programs round-trip
    through the persistent tier), and ``template_token`` — ``((fp, sig,
    pool_pad(d)), ...)`` in group order — is the template identity the
    fused cache key incorporates (members arrive canonically sorted, so
    the token is arrival-order independent; ``d`` is bucketed by
    :func:`_pool_pad` so a growing distinct-binding population
    re-specializes O(log d) times, not per distinct d)."""
    from repro.fuse.merge import CONST_BIND, slot_param

    def hole_value(bind_h, pdict):
        """``(supplied, value)`` of one hole: const-bind markers carry the
        literal value; param binds look up the ticket's params."""
        if isinstance(bind_h, tuple) and bind_h[0] == CONST_BIND:
            return True, bind_h[1]
        if bind_h not in pdict:
            return False, None
        return True, pdict[bind_h]

    by_fp = {t.fp: t for t in merged.templates}
    groups: list[_PoolGroup] = []
    gindex: dict[tuple, int] = {}
    member_tmaps: list[dict] = []
    slot_maps: list[dict] = []
    slot_names: list[dict] = []
    for m, plist in zip(members, params_by_member):
        tmap: dict[int, int] = {}
        smap: dict[int, list] = {}
        names: dict[int, str] = {}
        # parameter-free members still pool occurrences whose holes are all
        # const-bound (lifted templates) — their slot rides as an unbatched
        # reserved parameter
        if plist:
            pdict0 = plist[0] or {}
            for n in _maximal_cse_occurrences(merged, m.plan):
                fp = merged.template_ids[n.node_id]
                bind = merged.template_binds[n.node_id]
                tmpl = by_fp[fp]
                # an occurrence whose actual parameters are not all
                # supplied cannot be pooled; the member trace will raise
                # (or not reach it) exactly as the per-statement path would
                vals0 = {}
                for h in tmpl.holes:
                    ok, v = hole_value(bind[h], pdict0)
                    if not ok:
                        vals0 = None
                        break
                    vals0[h] = v
                if vals0 is None:
                    continue
                sig = param_signature(vals0)
                gk = (fp, sig)
                gi = gindex.get(gk)
                if gi is None:
                    gi = gindex[gk] = len(groups)
                    groups.append(_PoolGroup(
                        fp, sig, tmpl.node, tmpl.holes,
                        {h: _param_value(vals0[h]).dictionary
                         for h in tmpl.holes},
                        [], {},
                    ))
                g = groups[gi]
                slots = []
                for p in plist:
                    pd = p or {}
                    b = {h: hole_value(bind[h], pd)[1] for h in tmpl.holes}
                    key = tuple(_binding_key(b[h]) for h in tmpl.holes)
                    slot = g.index.get(key)
                    if slot is None:
                        slot = g.index[key] = len(g.bindings)
                        g.bindings.append(b)
                    slots.append(slot)
                tmap[n.node_id] = gi
                smap[n.node_id] = slots
                # canonical spelling: the ordinal among this member's
                # pooled occurrences (walk order is plan-structural and
                # the pooled subset is a function of the member's param
                # signature, so the name set — and with it the fused
                # argument pytree — reproduces exactly across processes)
                names[n.node_id] = slot_param(len(names))
        member_tmaps.append(tmap)
        slot_maps.append(smap)
        slot_names.append(names)
    # the cache token carries the *padded* pool size: binding counts that
    # land in the same d-bucket share one fused specialization (the exact
    # count still rides per-wave as cse_bindings in the stats)
    token = tuple((g.fp, g.sig, _pool_pad(len(g.bindings))) for g in groups)
    return groups, member_tmaps, slot_maps, slot_names, token


# ---------------------------------------------------------------------------
# compiled executables
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Executable:
    fn: Any  # () kwargs-free jitted callable wrapper, see Session._executable
    plan: R.RelNode
    out_dicts: dict  # column name -> DictEncoding | None (trace-time capture)
    stats: dict  # trace-time logical reads of one execution
    raw: Any = None  # untraced (table_args, param_args) closure (vmap source)


@dataclasses.dataclass
class _BatchedExecutable:
    fn: Any  # (batched_pargs, catalog_token) -> (mask (B,n), cols)
    plan: R.RelNode
    out_dicts: dict  # shared with the unbatched executable's capture
    stats: dict
    bucket: int


@dataclasses.dataclass
class _ShardedExecutable:
    fn: Any  # (batched_pargs, catalog_token) -> (mask (B,n), cols), mesh-placed
    plan: R.RelNode
    out_dicts: dict  # shared with the unbatched executable's capture
    stats: dict
    bucket: int
    devices: int  # data-parallel shard count the bucket spreads over


@dataclasses.dataclass
class _FuseMember:
    """One member of a fused program: a (statement plan, parameter
    signature) pair stacked over its own batch bucket."""

    plan: R.RelNode
    sig: tuple
    bucket: int
    pdicts: dict  # param name -> DictEncoding | None (host metadata)
    key: tuple  # (query fingerprint, signature, bucket) — cache identity


@dataclasses.dataclass
class _FusedExecutable:
    fn: Any  # (pargs_tuple, targs_tuple, catalog_token) -> ((mask, cols), ...)
    plans: list  # member plans, fusion order
    out_dicts: list  # per-member {column -> DictEncoding | None} capture
    stats: dict  # trace stats + merge stats (shared_subtrees, cse_*, ...)
    members: list  # _FuseMember descriptors, fusion order
    merged: Any = None  # repro.fuse.merge.FusedPlan (sharing maps + explain)
    eval_counts: dict | None = None  # pool key -> trace-time evaluations


@dataclasses.dataclass
class _PoolGroup:
    """One template pool of a fused program: a parameter-unified shared
    subtree × one binding signature, evaluated once per distinct binding.
    Two members binding the same template with the same value *signature*
    land in the same group and share its distinct-binding pool — the
    cross-statement unification the CSE engine exists for."""

    fp: tuple  # canonical parametric fingerprint (template identity)
    sig: tuple  # binding signature (param_signature over hole values)
    node: R.RelNode  # canonical template subtree (holes as params)
    holes: tuple  # canonical hole parameter names, slot order
    hole_dicts: dict  # hole -> DictEncoding | None (host metadata)
    bindings: list  # [{hole: value}] distinct, slot order
    index: dict  # binding key -> slot

    def spec(self) -> "_PoolGroup":
        """Structure-only copy for the fused closure: the jitted program
        reads fp/sig/node/holes/hole_dicts; baking a wave's binding
        values (and their byte keys) into a long-lived cache entry would
        pin them for the entry's lifetime."""
        return dataclasses.replace(self, bindings=[], index={})


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------


class Session:
    """Catalog + registry + plan/executable caches; the engine's public
    entry point.  ``prepare`` returns a :class:`PreparedStatement`;
    ``execute`` is prepare-and-run (sharing the same caches)."""

    #: bound on each cache (plans / executables / prepared handles)
    CACHE_CAP = 256

    def __init__(self, constraints: InlineConstraints | None = None,
                 cache_cap: int | None = None, store=None):
        self.catalog: dict[str, Table] = {}
        self.registry: dict[str, UdfDef] = {}
        self.constraints = constraints or InlineConstraints()
        cap = self.CACHE_CAP if cache_cap is None else cache_cap
        self._plans: _BoundedCache = _BoundedCache(cap)
        self._execs: _BoundedCache = _BoundedCache(cap)
        self._batch_execs: _BoundedCache = _BoundedCache(cap)
        self._shard_execs: _BoundedCache = _BoundedCache(cap)
        self._fuse_execs: _BoundedCache = _BoundedCache(cap)
        self._prepared: _BoundedCache = _BoundedCache(cap)
        # persistent plan tier: a repro.persist.PlanStore (or a directory
        # path — coerced here).  None = in-process caches only.  The store
        # is consulted on in-memory misses and written behind on compiles;
        # every store failure degrades to recompile (see _persist_load)
        if store is not None and not hasattr(store, "get"):
            from repro.persist.store import PlanStore

            store = PlanStore(store)
        self.store = store
        self._persist_extra = {
            "saves": 0, "save_errors": 0, "costs_loaded": 0, "costs_saved": 0,
        }
        self.cache_stats = {
            "plan_hits": 0, "plan_misses": 0,
            "exec_hits": 0, "exec_misses": 0,
            "batch_hits": 0, "batch_misses": 0,
            "shard_hits": 0, "shard_misses": 0,
            "fuse_hits": 0, "fuse_misses": 0,
            # cross-statement CSE: evaluations avoided by sharing (constant
            # refs beyond the first + template ticket-refs beyond their
            # distinct bindings), and total plan nodes covered by a shared
            # evaluation, both accumulated per fused wave
            "cse_hits": 0, "cse_shared_nodes": 0,
            # persistent tier: hits (loaded a compiled executable from the
            # store), misses (no entry), rejects (entry present but stale/
            # corrupt/unloadable — recompiled).  Monotone like every other
            # tier's counters
            "persist_hits": 0, "persist_misses": 0, "persist_rejects": 0,
        }
        # dispatched-but-unsynced AsyncResults, oldest first (backpressure)
        self._inflight: deque = deque()
        self.async_stats = {"inflight_waits": 0, "inflight_peak": 0}
        # resilience seam: a repro.resilience.faults.FaultInjector (or any
        # object with .check(site, statements)) installed by chaos tests;
        # None in production — the seams below are no-ops then
        self.fault_injector = None
        # cost-routing seam: a repro.cost.CostRouter, created lazily the
        # first time a routed statement is prepared (None until then — the
        # sampling seams below are no-ops and unrouted sessions pay nothing)
        self.cost_router = None

    def _ensure_router(self):
        if self.cost_router is None:
            from repro.cost.router import CostRouter

            self.cost_router = CostRouter(self)
            if self.store is not None:
                self._load_costs()
        return self.cost_router

    def _load_costs(self) -> int:
        """Warm-start the router's measured cost model from the store (no-op
        on a clean miss; stale/corrupt tables degrade to an empty model)."""
        from repro.persist import costs as _costs
        from repro.persist.store import PlanCacheError

        try:
            n = _costs.load_costs(self.store, self._content_env_token(),
                                  self.cost_router)
        except PlanCacheError:
            self.cache_stats["persist_rejects"] += 1
            return 0
        if n:
            self._persist_extra["costs_loaded"] += n
        return n

    def save_costs(self) -> bool:
        """Persist the cost router's measured wave-cost EMAs so a fresh
        worker routes warm.  Fault-window samples were excluded at intake
        (``CostRouter.suppress``), so the saved table is clean by
        construction.  Returns True when a table was written."""
        if self.store is None or self.cost_router is None:
            return False
        from repro.persist import costs as _costs

        try:
            ok = _costs.save_costs(self.store, self._content_env_token(),
                                   self.cost_router)
        except Exception:
            self._persist_extra["save_errors"] += 1
            return False
        if ok:
            self._persist_extra["costs_saved"] += 1
        return ok

    @property
    def persist_stats(self) -> dict:
        """The persistent tier's view: hit/miss/reject counters, write
        counts, cost-table traffic, and the store's on-disk footprint.
        ``{"enabled": False}`` when no store is attached."""
        if self.store is None:
            return {"enabled": False}
        return {
            "enabled": True,
            "hits": self.cache_stats["persist_hits"],
            "misses": self.cache_stats["persist_misses"],
            "rejects": self.cache_stats["persist_rejects"],
            **self._persist_extra,
            "store": self.store.stats(),
        }

    @property
    def cost_stats(self) -> dict:
        """The cost router's view: counters, measured per-configuration
        wave costs (EMA), and the recent decision log.  ``{"enabled":
        False}`` until a routed statement has been prepared."""
        if self.cost_router is None:
            return {"enabled": False}
        return self.cost_router.snapshot()

    def _fault(self, site: str, statements: tuple = ()) -> None:
        """Fault-injection seam: named executor sites call this with the
        statement fingerprints they serve; an installed injector may raise
        :class:`~repro.resilience.faults.InjectedFault` here."""
        fi = self.fault_injector
        if fi is not None:
            fi.check(site, statements)

    # -- DDL ---------------------------------------------------------------
    # name/table are positional-only so columns may be called "name"/"table"
    def create_table(self, name: str, table: Table | None = None, /, **arrays):
        t = table if table is not None else Table.from_arrays(**arrays)
        t.compute_stats()  # histograms for the optimizer (§Perf)
        self.catalog[name] = t
        return t

    def create_function(self, udf: UdfDef):
        self.registry[udf.name] = udf
        return udf

    # -- public API --------------------------------------------------------
    def prepare(self, query, policy: ExecutionPolicy | str = FROID
                ) -> "PreparedStatement":
        policy = resolve_policy(policy)
        node = query.node if isinstance(query, Q) else query
        # the handle cache additionally keys on the batch/shard knobs (they
        # are excluded from fingerprint() so plan/executable caches still
        # share, but two prepares with different knobs must not alias —
        # the knobs live on the returned statement's policy)
        key = (plan_fingerprint(node), policy.fingerprint(),
               policy.max_batch, policy.coalesce_window_s, policy.allow_async,
               policy.max_inflight, policy.shard_batches, policy.shard_token(),
               policy.fuse, policy.max_fused_statements, policy.route)
        ps = self._prepared.get(key)
        if ps is None:
            ps = PreparedStatement(self, node, policy)
            self._prepared[key] = ps
        if policy.route:
            self._ensure_router()
        ps._ensure_plan()  # cold: bind + optimize now
        return ps

    def execute(self, query, policy: ExecutionPolicy | str = FROID,
                params: dict | None = None) -> QueryResult:
        return self.prepare(query, policy).execute(params=params)

    def execute_many(self, query, policy: ExecutionPolicy | str = FROID,
                     params_list=()) -> list[QueryResult]:
        return self.prepare(query, policy).execute_many(params_list)

    def execute_async(self, query, policy: ExecutionPolicy | str = FROID,
                      params: dict | None = None) -> "AsyncResult":
        return self.prepare(query, policy).execute_async(params=params)

    def explain(self, query, policy: ExecutionPolicy | str = FROID) -> str:
        policy = resolve_policy(policy)
        node = query.node if isinstance(query, Q) else query
        plan, _ = self._cached_plan(node, plan_fingerprint(node), policy)
        return O.explain(plan)

    # -- cache-state tokens ------------------------------------------------
    def _catalog_token(self) -> tuple:
        return tuple(
            (name, _stamp(t), t.num_rows, tuple(t.columns))
            for name, t in sorted(self.catalog.items())
        )

    def _registry_token(self) -> tuple:
        return tuple(
            (name, _stamp(u)) for name, u in sorted(self.registry.items())
        )

    def _constraints_token(self) -> tuple:
        return _norm(self.constraints)

    def _env_token(self) -> tuple:
        return (self._catalog_token(), self._registry_token(),
                self._constraints_token())

    def _content_env_token(self) -> tuple:
        """The cross-process rendering of :meth:`_env_token`: stamps (valid
        only in this process) are replaced by content digests, so two
        workers that loaded identical catalogs/registries produce identical
        persistent cache keys.  Memoized against the stamp-based token —
        the digests are recomputed only when DDL actually changed
        something, not per lookup."""
        env = self._env_token()
        cached = getattr(self, "_content_env_cache", None)
        if cached is not None and cached[0] == env:
            return cached[1]
        token = (
            tuple((name, t.num_rows, tuple(t.columns),
                   _table_content_digest(t))
                  for name, t in sorted(self.catalog.items())),
            tuple((name, _udf_content_digest(u))
                  for name, u in sorted(self.registry.items())),
            self._constraints_token(),
        )
        self._content_env_cache = (env, token)
        return token

    # -- persistent plan tier ----------------------------------------------
    def _persist_store(self, policy: ExecutionPolicy):
        """The store an executable-tier miss should consult, or None (no
        store attached / the policy opted out via ``persist=False``)."""
        s = self.store
        return s if (s is not None and policy.persist) else None

    def _persist_key(self, kind: str, query_fp, policy: ExecutionPolicy,
                     sig: tuple = (), bucket: int = 0,
                     shard_token: tuple = (), template: tuple = ()) -> tuple:
        """The five-tier cache identity as one self-describing stable tuple:
        plan fingerprint x policy fingerprint x param signature x batch
        bucket x shard token x fused/CSE template tuple, plus the content
        env token.  ``assert_stable_key`` is the enforcement point — any
        process-local value (an ``id()``, a stamp, a live object) smuggled
        into a component raises here instead of silently degrading the
        cross-worker hit rate."""
        from repro.persist.keys import assert_stable_key

        key = ("plan", kind, query_fp, policy.fingerprint(), sig, bucket,
               shard_token, template, self._content_env_token())
        assert_stable_key(key)
        return key

    def _persist_load(self, store, key: tuple):
        """``(compiled_callable, meta) | None`` — typed degradation ladder:
        version-stamp mismatch and load failures count as rejects, damaged
        entries additionally warn (:class:`~repro.persist.PlanCacheWarning`)
        and are evicted.  Every failure path returns None: the caller
        recompiles, results are never wrong and never late by more than
        one compile."""
        from repro.persist import codec
        from repro.persist.store import (
            PlanCacheCorruptError,
            PlanCacheVersionError,
            PlanCacheWarning,
        )

        try:
            got = store.get(key)
        except PlanCacheVersionError:
            self.cache_stats["persist_rejects"] += 1
            return None
        except PlanCacheCorruptError as e:
            self.cache_stats["persist_rejects"] += 1
            warnings.warn(
                f"dropping damaged persistent plan entry ({e}); recompiling",
                PlanCacheWarning, stacklevel=3)
            store.delete(key)
            return None
        if got is None:
            self.cache_stats["persist_misses"] += 1
            return None
        meta, blob = got
        try:
            loaded = codec.load_compiled(blob)
        except Exception as e:  # native deserialize: anything can surface
            self.cache_stats["persist_rejects"] += 1
            warnings.warn(
                f"persistent plan entry failed to load "
                f"({type(e).__name__}: {e}); recompiling",
                PlanCacheWarning, stacklevel=3)
            store.delete(key)
            return None
        self.cache_stats["persist_hits"] += 1
        return loaded, meta

    def _persist_save(self, store, key: tuple, compiled, *, out_dicts,
                      stats, extra: dict | None = None) -> bool:
        """Write-behind save of a freshly-compiled executable; failures are
        counted, never raised (persistence is an optimization, not a
        correctness dependency)."""
        from repro.persist import codec

        try:
            blob = codec.pack_compiled(compiled)
            meta = {
                "out_dicts": codec.encode_dicts(out_dicts),
                "stats": codec.jsonable_stats(stats),
            }
            if extra:
                meta.update(extra)
            store.put(key, meta, blob)
        except Exception:
            self._persist_extra["save_errors"] += 1
            return False
        self._persist_extra["saves"] += 1
        return True

    # -- planning ----------------------------------------------------------
    def _build_plan(self, node: R.RelNode, policy: ExecutionPolicy) -> R.RelNode:
        plan = node
        # the query's intended output schema (before inlining widens rows)
        try:
            wanted = R.output_columns(plan, self.catalog)
        except Exception:
            wanted = None
        if policy.inline_udfs:
            binder = Binder(self.registry, self.constraints)
            plan = binder.bind(plan)
        if policy.optimize:
            plan = O.optimize(
                plan, self.catalog, required=set(wanted) if wanted else None
            )
        if wanted is not None:
            try:
                have = R.output_columns(plan, self.catalog)
            except Exception:
                have = None
            if have is not None and have != wanted:
                plan = R.Project(plan, wanted)
        return plan

    def _cached_plan(self, node: R.RelNode, query_fp: tuple,
                     policy: ExecutionPolicy) -> tuple[R.RelNode, bool]:
        """(plan, came-from-cache).  Keyed only on the plan-relevant policy
        axes — FROID and HEKATON runs of the same inlined query share."""
        key = (query_fp, policy.inline_udfs, policy.optimize, self._env_token())
        plan = self._plans.get(key)
        if plan is not None:
            self.cache_stats["plan_hits"] += 1
            return plan, True
        self.cache_stats["plan_misses"] += 1
        plan = self._build_plan(node, policy)
        self._plans[key] = plan
        return plan, False

    # -- compiled executables ----------------------------------------------
    def _catalog_args(self, token: tuple | None = None):
        """Catalog arrays as the jit argument pytree, cached per catalog
        token — rebuilding per call would put O(tables × columns) validity
        allocations inside every warm execute.  ``token`` lets callers that
        already computed the catalog token skip recomputing it."""
        if token is None:
            token = self._catalog_token()
        cached = getattr(self, "_args_cache", None)
        if cached is not None and cached[0] == token:
            return cached[1]
        args = {
            tname: {c: (col.data, col.validity()) for c, col in t.columns.items()}
            for tname, t in self.catalog.items()
        }
        self._args_cache = (token, args)
        return args

    def _executable(self, node: R.RelNode, query_fp: tuple,
                    policy: ExecutionPolicy, params: dict | None,
                    env_token: tuple | None = None
                    ) -> tuple[_Executable, bool, bool]:
        """(executable, exec-cache-hit, plan-cache-hit)."""
        sig = param_signature(params)
        if env_token is None:
            env_token = self._env_token()
        key = (query_fp, policy.fingerprint(), env_token, sig)
        entry = self._execs.get(key)
        if entry is not None:
            self.cache_stats["exec_hits"] += 1
            return entry, True, True
        self.cache_stats["exec_misses"] += 1
        self._fault("compile", (query_fp,))
        plan, plan_hit = self._cached_plan(node, query_fp, policy)

        # iterative hook for UDF calls left in the plan (froid OFF, or
        # hybrid plans where the inlining budget ran out).  'scan' mode is
        # the only jit-traceable interpreter, so the compiled path always
        # uses it regardless of policy.udf_mode.
        has_udf_calls = any(
            isinstance(e, S.UdfCall)
            for n in R.walk_plan(plan)
            for ex in n.exprs()
            for e in S.walk(ex)
        )
        hook = None
        if has_udf_calls:
            interp = Interpreter(self.catalog, self.registry, mode="scan")
            hook = interp.eval_udf_call

        # host-side metadata (dictionaries) stays captured; data goes by
        # argument so XLA cannot constant-fold the query away — warm calls
        # measure real execution.
        meta = {
            tname: {c: col.dictionary for c, col in t.columns.items()}
            for tname, t in self.catalog.items()
        }
        pdicts = {
            name: _param_value(v).dictionary for name, v in (params or {}).items()
        }
        out_dicts: dict = {}
        trace_stats: dict = {}

        def raw(table_args, param_args):
            catalog = {
                tname: Table(
                    {
                        c: Column(data, valid, meta[tname][c])
                        for c, (data, valid) in cols.items()
                    }
                )
                for tname, cols in table_args.items()
            }
            pvals = {
                name: S.Value(data, valid, pdicts[name])
                for name, (data, valid) in param_args.items()
            }
            ex = Executor(catalog, udf_column_evaluator=hook,
                          use_pallas_agg=policy.pallas_agg)
            out = ex.execute(plan, params=pvals)
            for n, c in out.table.columns.items():
                out_dicts[n] = c.dictionary  # host metadata, set at trace
            trace_stats.update(ex.stats)
            cols = {n: (c.data, c.validity()) for n, c in out.table.columns.items()}
            return out.mask, cols

        # persistent tier: on an in-memory miss, try loading the compiled
        # executable from the store before tracing; on a store miss, AOT
        # lower+compile once (which runs the trace and fills the capture
        # dicts) and write the artifact behind.  Either way `target` below
        # is called with the same (catalog_args, pargs) pytree the jitted
        # path would see — content-env-token keying guarantees shapes match.
        from repro.persist import codec as _codec

        store = self._persist_store(policy)
        target = None
        if store is not None:
            pkey = self._persist_key("exec", query_fp, policy, sig=sig)
            loaded = self._persist_load(store, pkey)
            if loaded is not None:
                target, pmeta = loaded
                out_dicts.update(_codec.decode_dicts(pmeta.get("out_dicts"))
                                 or {})
                trace_stats.update(pmeta.get("stats") or {})
            else:
                try:
                    pargs0 = {}
                    for pname, x in (params or {}).items():
                        v = _param_value(x)
                        pargs0[pname] = (v.data, v.validity())
                    target = jax.jit(raw).lower(
                        self._catalog_args(), pargs0).compile()
                    self._persist_save(store, pkey, target,
                                       out_dicts=out_dicts, stats=trace_stats)
                except Exception:
                    self._persist_extra["save_errors"] += 1
                    target = None
        if target is None:
            target = jax.jit(raw)

        def fn(param_values: dict | None = None,
               catalog_token: tuple | None = None):
            pargs = {}
            for pname, x in (param_values or {}).items():
                v = _param_value(x)
                pargs[pname] = (v.data, v.validity())
            return target(self._catalog_args(catalog_token), pargs)

        entry = _Executable(fn, plan, out_dicts, trace_stats, raw=raw)
        self._execs[key] = entry
        return entry, False, plan_hit

    def _batched_executable(self, node: R.RelNode, query_fp: tuple,
                            policy: ExecutionPolicy, params0: dict,
                            sig: tuple, bucket: int,
                            env_token: tuple | None = None
                            ) -> tuple[_BatchedExecutable, bool]:
        """(vmapped executable, batch-cache-hit).  The batched program is
        ``vmap`` of the unbatched raw plan closure over the parameter axis
        (catalog args broadcast), jitted once per (plan, policy, signature,
        batch bucket) — heterogeneous request streams re-specialize per
        bucket, not per distinct N."""
        if env_token is None:
            env_token = self._env_token()
        key = (query_fp, policy.fingerprint(), env_token, sig, bucket)
        entry = self._batch_execs.get(key)
        if entry is not None:
            self.cache_stats["batch_hits"] += 1
            return entry, True
        self.cache_stats["batch_misses"] += 1
        self._fault("compile", (query_fp,))
        # share the unbatched executable's raw closure and trace-time
        # capture dicts so warm execute() and execute_many() agree on
        # output dictionaries/stats regardless of which traced first
        base, _, _ = self._executable(node, query_fp, policy, params0, env_token)

        # persistent tier: the batched program persists independently of the
        # base executable (its own bucket-keyed entry).  On a store miss the
        # AOT compile traces base.raw under vmap — filling the shared
        # capture dicts exactly like the jit path would.
        store = self._persist_store(policy)
        target = None
        if store is not None:
            pkey = self._persist_key("batch", query_fp, policy, sig=sig,
                                     bucket=bucket)
            loaded = self._persist_load(store, pkey)
            if loaded is not None:
                target, _pmeta = loaded
            else:
                try:
                    target = jax.jit(
                        jax.vmap(base.raw, in_axes=(None, 0))).lower(
                        self._catalog_args(),
                        _batched_avals(params0, bucket)).compile()
                    self._persist_save(store, pkey, target,
                                       out_dicts=base.out_dicts,
                                       stats=base.stats)
                except Exception:
                    self._persist_extra["save_errors"] += 1
                    target = None
        if target is None:
            target = jax.jit(jax.vmap(base.raw, in_axes=(None, 0)))

        def fn(batched_pargs: dict, catalog_token: tuple | None = None):
            return target(self._catalog_args(catalog_token), batched_pargs)

        entry = _BatchedExecutable(fn, base.plan, base.out_dicts, base.stats,
                                   bucket)
        self._batch_execs[key] = entry
        return entry, False

    def _catalog_args_replicated(self, mesh, token: tuple, shard_token: tuple):
        """Catalog arg pytree broadcast to every device of ``mesh``, cached
        per (catalog token, mesh placement) — replication is a real
        cross-device transfer, so it must happen once per catalog state,
        not once per sharded dispatch.  A small LRU (not a single slot):
        statements sharded over different meshes interleave without
        re-replicating per call."""
        from repro.dist.sharding import replicated_sharding

        key = (token, shard_token)
        cache = getattr(self, "_shard_args_cache", None)
        if cache is None:
            cache = self._shard_args_cache = _BoundedCache(8)
        args = cache.get(key)
        if args is None:
            args = jax.device_put(self._catalog_args(token),
                                  replicated_sharding(mesh))
            cache[key] = args
        return args

    def _sharded_executable(self, node: R.RelNode, query_fp: tuple,
                            policy: ExecutionPolicy, params0: dict,
                            sig: tuple, bucket: int,
                            env_token: tuple | None = None
                            ) -> tuple[_ShardedExecutable, bool]:
        """(mesh-sharded executable, shard-cache-hit).  The same vmapped
        program as :meth:`_batched_executable`, but jitted with the stacked
        parameter axis sharded over the mesh's data axes
        (``repro.dist.sharding.pick_data_axes``) and the catalog replicated
        on every device.  Callers gate on divisibility: a bucket the data
        axes don't divide never reaches here (it runs on the replicated
        single-device path instead — rows are never padded onto a mesh
        that doesn't fit them)."""
        from repro.dist.sharding import batch_sharding

        if env_token is None:
            env_token = self._env_token()
        shard_token = policy.shard_token()
        key = (query_fp, policy.fingerprint(), env_token, sig, bucket,
               shard_token)
        entry = self._shard_execs.get(key)
        if entry is not None:
            self.cache_stats["shard_hits"] += 1
            return entry, True
        self.cache_stats["shard_misses"] += 1
        self._fault("compile", (query_fp,))
        base, _, _ = self._executable(node, query_fp, policy, params0, env_token)
        mesh = policy.mesh
        parg_sharding = batch_sharding(mesh, bucket)
        if parg_sharding is None:  # callers gate; keep the invariant loud
            raise ValueError(
                f"bucket {bucket} is not divisible by the mesh data axes"
            )
        # persistent tier: the sharded program can only round-trip when its
        # input shardings are explicit (a serialized executable is
        # specialized to placements, not just avals), so the AOT path jits
        # with in_shardings = (replicated catalog, sharded param axis) —
        # exactly the placements fn below commits its inputs to.  Any
        # failure (lowering, serialization, a store reject) falls back to
        # the inference-jitted path.
        from repro.dist.sharding import replicated_sharding

        store = self._persist_store(policy)
        target = None
        if store is not None:
            pkey = self._persist_key("shard", query_fp, policy, sig=sig,
                                     bucket=bucket, shard_token=shard_token)
            loaded = self._persist_load(store, pkey)
            if loaded is not None:
                target, _pmeta = loaded
            else:
                try:
                    target = jax.jit(
                        jax.vmap(base.raw, in_axes=(None, 0)),
                        in_shardings=(replicated_sharding(mesh),
                                      parg_sharding)).lower(
                        self._catalog_args(),
                        _batched_avals(params0, bucket)).compile()
                    self._persist_save(store, pkey, target,
                                       out_dicts=base.out_dicts,
                                       stats=base.stats)
                except Exception:
                    self._persist_extra["save_errors"] += 1
                    target = None
        if target is None:
            # one leading-axis spec serves every stacked-param leaf
            # (trailing dims replicate); catalog args broadcast whole
            target = jax.jit(jax.vmap(base.raw, in_axes=(None, 0)))

        def fn(batched_pargs: dict, catalog_token: tuple | None = None):
            cats = self._catalog_args_replicated(
                mesh, catalog_token if catalog_token is not None
                else self._catalog_token(), shard_token)
            pargs = jax.device_put(batched_pargs, parg_sharding)
            return target(cats, pargs)

        entry = _ShardedExecutable(fn, base.plan, base.out_dicts, base.stats,
                                   bucket, policy.shard_devices())
        self._shard_execs[key] = entry
        return entry, False

    # -- multi-statement fusion ----------------------------------------------
    def _merged_for(self, members: list, env_token: tuple):
        """The merge pass's :class:`~repro.fuse.merge.FusedPlan` for this
        member set, cached — the host consults the sharing maps on every
        wave (warm or cold) to plan template bindings, and the walk must
        not re-run per drain.

        The key includes the member plans' identities: the sharing maps
        are ``node_id``-keyed, so a plan rebuilt after a ``_plans``-cache
        eviction (same env token, fresh node ids) must get a fresh merge,
        not a stale FusedPlan whose marks match nothing.  Plan identity is
        the session stamp (monotonic, never recycled) — unlike a raw
        ``id()`` it cannot alias a dead plan's key even after eviction."""
        key = (tuple(m.key for m in members), env_token,
               tuple(_stamp(m.plan) for m in members))
        cache = getattr(self, "_merge_cache", None)
        if cache is None:
            cache = self._merge_cache = _BoundedCache(64)
        merged = cache.get(key)
        if merged is None:
            from repro.fuse.merge import merge_plans

            merged = merge_plans([m.plan for m in members])
            cache[key] = merged
        return merged

    def _fused_executable(self, members: list, policy: ExecutionPolicy,
                          shard: bool, env_token: tuple, merged,
                          groups: list, member_tmaps: list,
                          slot_names: list, template_token: tuple,
                          example_args: tuple | None = None
                          ) -> tuple[_FusedExecutable, bool]:
        """(fused executable, fuse-cache-hit).  One jitted program carrying
        every member: the merge pass's shared subtrees execute once, each
        template pool once per distinct binding, then each member's plan
        vmaps over its own stacked parameter axis (see
        ``repro.fuse.program``).  Keyed by the member tuple in canonical
        (sorted) order × policy × env token × **template identity**
        (``(fingerprint, binding signature, distinct-binding count)`` per
        pool group), so a mixed queue arriving in any order warm-hits, a
        changed distinct-binding count honestly re-specializes instead of
        hiding a retrace behind a "hit", and any DDL/catalog poke
        invalidates every member at once via the env token."""
        shard_token = policy.shard_token() if shard else ()
        # plan identity rides the key alongside the member keys: the slot
        # protocol and member_tmaps are node_id-keyed, so a plan rebuilt
        # after a _plans-cache eviction must re-specialize here too (a
        # stale entry would silently answer no template occurrence).  Plan
        # identity is the session stamp — monotonic and never recycled, so
        # unlike raw id() an evicted plan's key can never alias a live one.
        key = (tuple(m.key for m in members),
               tuple(_stamp(m.plan) for m in members), policy.fingerprint(),
               env_token, shard, shard_token, template_token)
        entry = self._fuse_execs.get(key)
        if entry is not None:
            self.cache_stats["fuse_hits"] += 1
            return entry, True
        self.cache_stats["fuse_misses"] += 1
        # persistent tier (unsharded waves): template pools gather through
        # reserved slot parameters spelled by occurrence *ordinal* (see
        # _plan_template_groups), so the fused argument pytree — dict keys
        # included — reproduces exactly in a fresh process and template
        # waves round-trip through the store like template-free ones.
        # Sharded fused programs fall back to their members' shard-tier
        # entries instead.  The persist key itself is fully stable: member
        # (fingerprint, sig, bucket) keys + the template token — no plan
        # stamps, no ids (assert_stable_key enforces this, and rejects the
        # pre-PR-10 node_id-shaped slot spellings outright).
        from repro.persist import codec as _codec

        store = self._persist_store(policy)
        persistable = (store is not None and not shard
                       and example_args is not None)
        if persistable:
            pkey = self._persist_key(
                "fused", tuple(m.key for m in members), policy,
                template=template_token)
            loaded = self._persist_load(store, pkey)
            if loaded is not None:
                compiled, pmeta = loaded
                out_dicts = [_codec.decode_dicts(d) or {}
                             for d in pmeta.get("out_dicts_list") or ()]
                trace_stats = dict(pmeta.get("stats") or {})

                def fn(pargs_tuple, targs_tuple,
                       catalog_token: tuple | None = None):
                    return compiled(self._catalog_args(catalog_token),
                                    pargs_tuple, targs_tuple)

                entry = _FusedExecutable(
                    fn, [m.plan for m in members], out_dicts, trace_stats,
                    members, merged, {})
                self._fuse_execs[key] = entry
                return entry, False
        self._fault("compile", tuple(m.key[0] for m in members))
        from repro.fuse.program import build_fused_raw

        raw, out_dicts, trace_stats, merged, eval_counts = build_fused_raw(
            self, members, policy, merged, [g.spec() for g in groups],
            member_tmaps, slot_names)
        jitted = jax.jit(raw)
        if persistable:
            try:
                compiled = jitted.lower(self._catalog_args(),
                                        *example_args).compile()
                self._persist_save(
                    store, pkey, compiled, out_dicts=None, stats=trace_stats,
                    extra={"out_dicts_list":
                           [_codec.encode_dicts(d) for d in out_dicts]})
                jitted = compiled  # single compile: reuse the AOT artifact
            except Exception:
                self._persist_extra["save_errors"] += 1
        if shard:
            from repro.dist.sharding import batch_sharding, replicated_sharding

            mesh = policy.mesh
            # parameter-free members are unbatched: their (empty) arg
            # pytree replicates; batched members shard their stacked axis;
            # template binding stacks replicate (every member row may
            # gather any pool slot)
            shardings = tuple(
                batch_sharding(mesh, m.bucket) if m.sig
                else replicated_sharding(mesh)
                for m in members
            )

            def fn(pargs_tuple, targs_tuple,
                   catalog_token: tuple | None = None):
                cats = self._catalog_args_replicated(
                    mesh, catalog_token if catalog_token is not None
                    else self._catalog_token(), shard_token)
                placed = tuple(
                    jax.device_put(p, s) for p, s in zip(pargs_tuple, shardings)
                )
                targs = jax.device_put(targs_tuple,
                                       replicated_sharding(mesh))
                return jitted(cats, placed, targs)
        else:
            def fn(pargs_tuple, targs_tuple,
                   catalog_token: tuple | None = None):
                return jitted(self._catalog_args(catalog_token), pargs_tuple,
                              targs_tuple)

        entry = _FusedExecutable(fn, [m.plan for m in members], out_dicts,
                                 trace_stats, members, merged, eval_counts)
        self._fuse_execs[key] = entry
        return entry, False

    def execute_fused(self, calls) -> list[QueryResult]:
        """Execute a mixed-statement call list — ``[(stmt, params), ...]``
        — through as few fused device programs as fusability allows.

        Calls whose statements may share a program (same session, policy
        fingerprint and sharding placement; ``policy.fuse`` on; pure
        plans — see ``repro.fuse.analysis``) coalesce into fused programs
        of at most ``policy.max_fused_statements`` distinct statements;
        everything else (eager policies, foreign sessions, singleton
        groups) falls back to the per-statement ``execute_many`` path.

        Returns one :class:`QueryResult` per call, in input order,
        element-wise equal to the per-statement serial loop.  Fused
        results carry ``stats['fused'] / fused_statements /
        fused_programs / shared_subtrees`` — the shared-scan evidence."""
        from repro.fuse.analysis import partition_calls

        calls = [(stmt, dict(p) if p else {}) for stmt, p in calls]
        if not calls:
            return []
        results: list[QueryResult | None] = [None] * len(calls)
        groups, fallbacks = partition_calls(self, calls)
        for stmt, items in fallbacks:
            rs = stmt.execute_many([p for _, p in items])
            for (i, _), r in zip(items, rs):
                results[i] = r
        for group in groups:
            self._run_fused(group, results)
        return results  # type: ignore[return-value]

    def _run_fused(self, group: list, results: list) -> None:
        """Run one fused group — ``[(index, stmt, params), ...]`` with ≥ 2
        distinct statements and compatible policies — and scatter its
        QueryResults into ``results``."""
        env_token = self._env_token()
        policy = group[0][1].policy  # fingerprint-equal across the group
        # member = one (statement, signature) pair stacked over its tickets
        order: list[tuple] = []
        by_key: dict[tuple, dict] = {}
        for idx, stmt, params in group:
            sig = param_signature(params)
            k = (stmt._query_fp, sig)
            ent = by_key.get(k)
            if ent is None:
                ent = by_key[k] = {"stmt": stmt, "sig": sig,
                                   "idxs": [], "params": []}
                order.append(k)
            ent["idxs"].append(idx)
            ent["params"].append(params)
        # one fused wave per drain: tickets beyond the mesh-scaled batch
        # bound ride the per-statement path (already batched + pipelined).
        # max_batch is a non-identity knob, so fingerprint-equal members
        # may disagree — honor the strictest bound (and keep the cap, and
        # therefore the buckets and cache keys, arrival-order independent)
        cap = max(1, min(s.policy.max_batch for _, s, _ in group)
                  * policy.shard_devices())
        for k in order:
            ent = by_key[k]
            if len(ent["params"]) > cap:
                extra_i, extra_p = ent["idxs"][cap:], ent["params"][cap:]
                ent["idxs"], ent["params"] = ent["idxs"][:cap], ent["params"][:cap]
                for i, r in zip(extra_i, ent["stmt"].execute_many(extra_p)):
                    results[i] = r
        # canonical member order: fused cache keys are insensitive to the
        # queue's arrival order (repr: fingerprints are not comparable)
        order.sort(key=repr)
        members: list[_FuseMember] = []
        for k in order:
            ent = by_key[k]
            stmt = ent["stmt"]
            plan, _ = self._cached_plan(stmt.node, stmt._query_fp, stmt.policy)
            # parameter-free members execute once, unbatched — every ticket
            # shares the single result (mirrors execute_many's group path)
            bucket = 1 if not ent["sig"] else batch_bucket(len(ent["params"]), cap)
            pdicts = {
                name: _param_value(v).dictionary
                for name, v in ent["params"][0].items()
            }
            members.append(_FuseMember(plan, ent["sig"], bucket, pdicts,
                                       (stmt._query_fp, ent["sig"], bucket)))
        devices = policy.shard_devices()
        shard = False
        if devices > 1:
            from repro.dist.sharding import data_axis_size, pick_data_axes

            # one program, one placement: shard whenever ANY batched
            # member's bucket divides the data axes.  A non-dividing
            # batched member no longer demotes the whole program to
            # replicated — its bucket pads up to the next multiple of the
            # data-axis product (padding repeats the last ticket, exactly
            # like power-of-two bucket padding) so every batched member
            # shards under one placement.  The cap is max_batch × devices
            # — itself a multiple of the axis product — so a padded
            # bucket never exceeds it.  Only when NO batched member
            # divides (or none is batched) does the program replicate;
            # parameter-free members are unbatched and always replicate.
            batched = [m for m in members if m.sig]
            shard = any(
                pick_data_axes(policy.mesh, m.bucket) is not None
                for m in batched
            )
            if shard:
                n = data_axis_size(policy.mesh)
                for m in batched:
                    if pick_data_axes(policy.mesh, m.bucket) is None:
                        m.bucket += (-m.bucket) % n
                        m.key = (m.key[0], m.key[1], m.bucket)
        # cross-statement CSE: plan the template binding pools from the
        # wave's actual ticket values (the merge maps are cached; only the
        # binding dedup runs per wave)
        merged = self._merged_for(members, env_token)
        groups, member_tmaps, slot_maps, slot_names, template_token = \
            _plan_template_groups(merged, members,
                                  [by_key[k]["params"] for k in order])
        # ticket params stack BEFORE the executable lookup: the persistent
        # tier AOT-lowers against these exact argument pytrees on a cold
        # save.  Stacking time still counts into the wave's elapsed (t0 is
        # rewound by stack_s below); compile time still does not.
        pargs_tuple = []
        t0 = time.perf_counter()
        for m, k, smap, names in zip(members, order, slot_maps, slot_names):
            plist = by_key[k]["params"]
            if m.sig:
                padded = plist + [plist[-1]] * (m.bucket - len(plist))
                pargs = _stack_params(padded)
                for nid, slots in smap.items():
                    # each occurrence's pool-slot index rides the stacked
                    # axis as a reserved parameter (padding repeats the
                    # last ticket's slot, matching the padded params)
                    s = slots + [slots[-1]] * (m.bucket - len(slots))
                    pargs[names[nid]] = (
                        jnp.asarray(np.asarray(s, np.int32)),
                        jnp.ones((m.bucket,), bool),
                    )
                pargs_tuple.append(pargs)
            else:
                # parameter-free member: unbatched, no stacked args — but
                # const-bound template occurrences (lifted templates) still
                # gather their pool slot through the reserved parameter
                pargs = {}
                for nid, slots in smap.items():
                    pargs[names[nid]] = (
                        jnp.asarray(slots[0], jnp.int32), jnp.asarray(True))
                pargs_tuple.append(pargs)
        # binding pools pad to their d-bucket (repeat the last binding):
        # the stacked leading axis is what the fused closure specializes
        # on, so all counts in one bucket share the jitted program; padded
        # slots are evaluated and never referenced by any ticket's slot
        targs_tuple = tuple(
            _stack_params(
                g.bindings
                + [g.bindings[-1]] * (_pool_pad(len(g.bindings))
                                      - len(g.bindings)))
            for g in groups)
        stack_s = time.perf_counter() - t0
        entry, hit = self._fused_executable(
            members, policy, shard, env_token, merged, groups, member_tmaps,
            slot_names, template_token,
            example_args=(tuple(pargs_tuple), targs_tuple))
        t0 = time.perf_counter() - stack_s
        wave_fps = tuple(m.key[0] for m in members)
        self._fault("dispatch", wave_fps)
        outs = entry.fn(tuple(pargs_tuple), targs_tuple, env_token[0])
        t_dispatch = time.perf_counter() - t0
        self._fault("sync", wave_fps)
        jax.block_until_ready([mask for mask, _ in outs])
        elapsed = time.perf_counter() - t0
        n_stmts = len({m.key[0] for m in members})
        # sharing evidence: evaluations avoided this wave (constant refs
        # beyond the first evaluation + template ticket-refs beyond their
        # distinct bindings) and the covered-node total
        t_refs = sum(len(s) for smap in slot_maps for s in smap.values())
        t_evals = sum(len(g.bindings) for g in groups)
        t_slots = sum(_pool_pad(len(g.bindings)) for g in groups)
        m_stats = merged.stats
        # subtrahend is the distinct *maximal* fingerprint count — the pool
        # also holds nested entries, which are not separate evaluations the
        # per-statement path would have paid.  Template savings subtract
        # the *padded* slot count: padded pool slots are real device
        # evaluations, so counting them as avoided would overstate sharing
        self.cache_stats["cse_hits"] += (
            max(0, m_stats["shared_refs"] - m_stats["shared_maximal_subtrees"])
            + max(0, t_refs - t_slots)
        )
        self.cache_stats["cse_shared_nodes"] += m_stats["cse_shared_nodes"]
        n_tickets = sum(len(by_key[k]["idxs"]) for k in order)
        router = self.cost_router
        if router is not None:
            router.observe_fused(
                wave_fps, elapsed, n_tickets,
                meta={"cse_bindings": t_evals, "cse_pool_slots": t_slots,
                      "cse_ticket_refs": t_refs})
        fused_explain = merged.explain()
        for j, (m, k) in enumerate(zip(members, order)):
            ent = by_key[k]
            mask, cols = outs[j]
            stats = {
                **entry.stats, "compiled": True, "batched": True,
                "fused": True, "fused_programs": 1,
                "fused_statements": n_stmts, "fused_members": len(members),
                "batch_size": len(ent["params"]), "batch_bucket": m.bucket,
                "dispatch_s": t_dispatch, "sync_s": elapsed - t_dispatch,
                # this wave's template pooling (trace-level cse_* counters
                # ride in from entry.stats via the merge pass)
                "cse_template_groups": len(groups),
                "cse_bindings": t_evals,
                "cse_pool_slots": t_slots,
                "cse_template_ticket_refs": t_refs,
                # wave-level figures (dispatch_s/sync_s/cse_*) are COPIED
                # into every ticket's result in this wave; aggregators
                # summing across results must divide by wave_tickets or
                # they double-count the wave (the router samples once at
                # the seam instead)
                "wave_tickets": n_tickets,
                "fused_explain": fused_explain,
            }
            if shard:
                stats["sharded"] = True
                stats["shard_devices"] = devices
            out_dicts = entry.out_dicts[j]

            if not m.sig:
                # unbatched member: one shared materialization serves
                # every ticket (distinct QueryResult shells, like
                # execute_many's parameter-free group)
                cell: dict = {}

                def mat_shared(mask=mask, cols=cols, out_dicts=out_dicts,
                               cell=cell):
                    if "v" not in cell:
                        cell["v"] = MaskedTable(
                            Table({n: Column(data, valid, out_dicts.get(n))
                                   for n, (data, valid) in cols.items()}),
                            mask,
                        )
                    return cell["v"]

                for i in ent["idxs"]:
                    results[i] = QueryResult(
                        None, m.plan, elapsed, dict(stats),
                        policy=ent["stmt"].policy, cache_hit=hit,
                        materialize=mat_shared,
                    )
                continue

            def materialize(row, mask=mask, cols=cols, out_dicts=out_dicts):
                table = Table(
                    {n: Column(data[row], valid[row], out_dicts.get(n))
                     for n, (data, valid) in cols.items()}
                )
                return MaskedTable(table, mask[row])

            for row, i in enumerate(ent["idxs"]):
                results[i] = QueryResult(
                    None, m.plan, elapsed, dict(stats),
                    policy=ent["stmt"].policy, cache_hit=hit,
                    materialize=(lambda row=row, mat=materialize: mat(row)),
                )

    # -- async backpressure --------------------------------------------------
    @property
    def inflight(self) -> int:
        """Dispatched-but-unsynced ``execute_async`` calls right now."""
        return len(self._inflight)

    def _admit_async(self, bound: int) -> None:
        """Make room for one more in-flight dispatch: reap already-ready
        results for free, then block on the oldest in-flight dispatch while
        the session is at the bound (the producer stalls here)."""
        dq = self._inflight
        while dq and dq[0].done():
            dq.popleft()._released = True
        while len(dq) >= max(1, bound):
            self.async_stats["inflight_waits"] += 1
            oldest = dq.popleft()
            oldest._released = True
            if oldest._marker is not None:
                jax.block_until_ready(oldest._marker)


# ---------------------------------------------------------------------------
# PreparedStatement
# ---------------------------------------------------------------------------


class PreparedStatement:
    """A query bound to a session + policy.  Calling conventions:

    * ``execute(params=…) -> QueryResult`` — the client path.  Cold call
      plans + binds (+ jits under a compiling policy); warm calls reuse the
      session caches and set ``QueryResult.cache_hit``.
    * ``stmt(params=…)`` — the raw device-level call of the compiled
      executable (mask + columns, nothing materialized); what benchmark
      timing loops invoke.
    """

    def __init__(self, session: Session, node: R.RelNode,
                 policy: ExecutionPolicy):
        self.session = session
        self.node = node
        self.policy = policy
        self._query_fp = plan_fingerprint(node)
        self._interp: Interpreter | None = None
        # stamp of the last plan this statement executed eagerly — a
        # plan-cache hit only counts as warm once *this statement* has run
        # that plan before (prepare builds the plan; the first execute is
        # still the cold half of the lifecycle)
        self._executed_plan: int | None = None

    # -- plumbing ----------------------------------------------------------
    def _ensure_plan(self) -> R.RelNode:
        plan, _ = self.session._cached_plan(self.node, self._query_fp, self.policy)
        return plan

    @property
    def plan(self) -> R.RelNode:
        return self._ensure_plan()

    def explain(self) -> str:
        return O.explain(self._ensure_plan())

    def _eager_interp(self) -> Interpreter:
        # kept across executes so the per-statement plan cache stays warm —
        # but rebuilt if the session's catalog/registry dicts were rebound
        # wholesale (benchmarks assign `db.catalog = {...}`); the identity
        # check is on live objects, so it cannot be fooled by id reuse
        interp = self._interp
        if (interp is None
                or interp.catalog is not self.session.catalog
                or interp.registry is not self.session.registry):
            interp = self._interp = Interpreter(
                self.session.catalog, self.session.registry,
                mode=self.policy.udf_mode,
                jit_statements=self.policy.jit_statements,
            )
        return interp

    # -- cost routing ------------------------------------------------------
    def _route_target(self) -> "PreparedStatement":
        """The statement the cost router currently picks for this routed
        statement — ``self`` when the incumbent policy wins, else a
        delegate prepared under the chosen policy.  The delegate's policy
        has ``route=False`` (one routing decision per call, never a
        chain), but its samples still train the router — it is the
        session's router, keyed by policy fingerprint."""
        router = self.session._ensure_router()
        pol = router.choose_policy(self)
        if pol.fingerprint() == self.policy.fingerprint():
            return self
        return self.session.prepare(self.node, pol.routed(False))

    # -- execution ---------------------------------------------------------
    def __call__(self, params: dict | None = None):
        """Raw call: device outputs only (see class docstring)."""
        if not self.policy.compile_plan:
            return self.execute(params=params).masked.mask
        env_token = self.session._env_token()
        entry, _, _ = self.session._executable(
            self.node, self._query_fp, self.policy, params, env_token
        )
        return entry.fn(params, env_token[0])

    def execute(self, params: dict | None = None) -> QueryResult:
        if self.policy.route and self.policy.compile_plan:
            target = self._route_target()
            if target is not self:
                return target.execute(params=params)
        if self.policy.compile_plan:
            return self._execute_compiled(params)
        return self._execute_eager(params)

    # -- batched execution -------------------------------------------------
    def execute_many(self, params_list) -> list[QueryResult]:
        """Execute once per parameter set, set-oriented: same-signature
        sets are stacked into one device program (``vmap`` over the param
        axis; tables broadcast) instead of N dispatch+sync round trips.
        Mixed-signature lists split into per-signature sub-batches; batches
        larger than ``policy.max_batch`` split into chunks.  Returns one
        :class:`QueryResult` per input, in input order, element-wise equal
        to the serial ``execute`` loop.

        A policy carrying a mesh (``policy.sharded(mesh)``) shards the
        stacked parameter axis over the mesh's data axes: ``max_batch``
        bounds the *per-device* batch, so one mesh dispatch carries up to
        ``max_batch × shard_devices()`` parameter sets.  Sharding is
        divisibility-gated per bucket — buckets the data axes don't divide
        (small remainders, tiny batches) run on the replicated
        single-device path, never padded onto a mesh that doesn't fit.

        Chunked dispatches are **pipelined**: every chunk is dispatched
        before any chunk syncs (bounded by ``policy.max_inflight`` unsynced
        dispatches — past the bound a new dispatch first syncs the oldest),
        then one barrier at the end collects them all, so host-side
        stacking of chunk i+1 overlaps device compute of chunk i.
        ``stats['pipelined_chunks']`` reports how many chunks the call
        dispatched before that barrier.

        Results materialize lazily from the shared device batch, so an
        unmaterialized result keeps its whole bucket's outputs alive —
        callers holding results long-term should touch ``masked`` (or
        ``table``) to shrink retention to their own rows."""
        params_list = [dict(p) if p else {} for p in params_list]
        if not params_list:
            return []
        if self.policy.route and self.policy.compile_plan:
            target = self._route_target()
            if target is not self:
                return target.execute_many(params_list)
        if not self.policy.compile_plan:
            # eager policies have no device program to batch; stay serial
            return [self.execute(params=p) for p in params_list]
        env_token = self.session._env_token()
        groups: dict[tuple, list[int]] = {}
        for i, p in enumerate(params_list):
            groups.setdefault(param_signature(p), []).append(i)
        results: list[QueryResult | None] = [None] * len(params_list)
        pending: list[dict] = []  # dispatched-but-unsynced chunk records
        for sig, idxs in groups.items():
            if not sig:
                # parameter-free: every invocation is the same program run —
                # one execution serves the whole group, surfaced as distinct
                # QueryResult shells (per-result stats stay independent)
                r = self._execute_compiled(None)
                for i in idxs:
                    results[i] = QueryResult(
                        r.masked, r.plan, r.elapsed_s, dict(r.stats),
                        policy=r.policy, cache_hit=r.cache_hit,
                    )
                continue
            # mesh capacity: max_batch bounds the per-device batch
            cap = max(1, self.policy.max_batch * self.policy.shard_devices())
            for s in range(0, len(idxs), cap):
                chunk = idxs[s:s + cap]
                self._dispatch_batch(chunk, [params_list[i] for i in chunk],
                                     sig, env_token, pending, cap)
        # the barrier: all chunks are in flight; sync in dispatch order
        npend = len(pending)
        for rec in pending:
            self._finalize_batch(rec, results, npend)
        return results  # type: ignore[return-value]

    def _dispatch_batch(self, idxs: list[int], plist: list[dict], sig: tuple,
                        env_token: tuple, pending: list,
                        cap: int | None = None) -> None:
        """Dispatch one chunk (no sync) and append its record to
        ``pending`` for the caller's end-of-call barrier."""
        k = len(plist)
        cap_b = cap if cap is not None else self.policy.max_batch
        bucket = batch_bucket(k, cap_b)
        router = self.session.cost_router
        if router is not None and self.policy.route:
            # bucket routing: ride an already-measured larger bucket when
            # that beats cold-compiling the natural one (bucket ≥ k always
            # holds — rides only go up, and padding repeats the last set)
            bucket = router.choose_bucket(
                self, sig, k, bucket, cap_b,
                shard=self.policy.shard_devices() > 1)
        devices = self.policy.shard_devices()
        shard = False
        if devices > 1:
            from repro.dist.sharding import pick_data_axes

            shard = pick_data_axes(self.policy.mesh, bucket) is not None
            if not shard:
                # replicated fallback: the mesh-capacity bucket would land
                # whole on one device, so re-chunk to the per-device bound
                # (max_batch is a single-device promise, not just a knob)
                mb = max(1, self.policy.max_batch)
                if k > mb:
                    for s in range(0, k, mb):
                        self._dispatch_batch(idxs[s:s + mb], plist[s:s + mb],
                                             sig, env_token, pending, mb)
                    return
                bucket = batch_bucket(k, mb)
        if shard:
            entry, hit = self.session._sharded_executable(
                self.node, self._query_fp, self.policy, plist[0], sig,
                bucket, env_token,
            )
        else:
            entry, hit = self.session._batched_executable(
                self.node, self._query_fp, self.policy, plist[0], sig,
                bucket, env_token,
            )
        # runahead bound: past max_inflight unsynced chunks, sync the
        # oldest before issuing another dispatch (same backpressure rule
        # as execute_async — the host cannot queue unbounded device work)
        bound = max(1, self.policy.max_inflight)
        unsynced = [r for r in pending if not r["synced"]]
        while len(unsynced) >= bound:
            oldest = unsynced.pop(0)
            jax.block_until_ready(oldest["mask"])
            oldest["synced"] = True
        # pad to the bucket by repeating the last param set; padding rows
        # are computed and discarded (never surfaced in results)
        padded = plist + [plist[-1]] * (bucket - k)
        t0 = time.perf_counter()
        pargs = _stack_params(padded)
        self.session._fault("dispatch", (self._query_fp,))
        mask, cols = entry.fn(pargs, env_token[0])
        t_dispatch = time.perf_counter() - t0
        pending.append({
            "idxs": idxs, "entry": entry, "hit": hit, "mask": mask,
            "cols": cols, "k": k, "bucket": bucket, "shard": shard,
            "devices": devices, "t0": t0, "dispatch_s": t_dispatch,
            "synced": False, "sig": sig,
        })

    def _finalize_batch(self, rec: dict, results: list,
                        pipelined: int) -> None:
        """Sync one dispatched chunk and build its QueryResults.
        ``sync_s`` is the wait from dispatch end to this chunk's barrier
        arrival — under pipelining that wait overlaps the later chunks'
        host-side stacking, which is the point."""
        entry, mask, cols = rec["entry"], rec["mask"], rec["cols"]
        self.session._fault("sync", (self._query_fp,))
        jax.block_until_ready(mask)
        rec["synced"] = True
        elapsed = time.perf_counter() - rec["t0"]
        stats = {
            **entry.stats, "compiled": True, "batched": True,
            "batch_size": rec["k"], "batch_bucket": rec["bucket"],
            "dispatch_s": rec["dispatch_s"],
            "sync_s": elapsed - rec["dispatch_s"],
            "pipelined_chunks": pipelined,
            # chunk-level timings are copied into every ticket's result in
            # this chunk; aggregators summing across results must divide
            # by wave_tickets or they double-count the chunk
            "wave_tickets": rec["k"],
        }
        if rec["shard"]:
            stats["sharded"] = True
            stats["shard_devices"] = rec["devices"]
        router = self.session.cost_router
        if router is not None:
            router.observe_many(self._query_fp, self.policy, rec["sig"],
                                rec["bucket"], elapsed, rec["k"],
                                shard=rec["shard"])

        def materialize(j: int) -> MaskedTable:
            table = Table(
                {n: Column(data[j], valid[j], entry.out_dicts.get(n))
                 for n, (data, valid) in cols.items()}
            )
            return MaskedTable(table, mask[j])

        for j, i in enumerate(rec["idxs"]):
            results[i] = QueryResult(
                None, entry.plan, elapsed, dict(stats), policy=self.policy,
                cache_hit=rec["hit"],
                materialize=(lambda j=j: materialize(j)),
            )

    # -- async execution ---------------------------------------------------
    def execute_async(self, params: dict | None = None) -> AsyncResult:
        """Dispatch without waiting: the device call is issued and a future
        returned immediately; ``block_until_ready`` is deferred to result
        access, so callers pipeline host work (or further dispatches)
        against device compute.  Policies with ``allow_async=False`` (or no
        compiled plan) degrade to synchronous execution behind the same
        interface.

        In-flight dispatches are bounded per session by
        ``policy.max_inflight``: at the bound, a new dispatch first blocks
        on the oldest unsynced one (and ``AsyncResult.result()`` releases
        its slot), so a producer outrunning the device stalls instead of
        queueing unbounded work."""
        if self.policy.route and self.policy.compile_plan:
            target = self._route_target()
            if target is not self:
                return target.execute_async(params=params)
        if not (self.policy.compile_plan and self.policy.allow_async):
            return AsyncResult(self.execute(params=params))
        self.session._admit_async(self.policy.max_inflight)
        env_token = self.session._env_token()
        entry, exec_hit, plan_hit = self.session._executable(
            self.node, self._query_fp, self.policy, params, env_token
        )
        t0 = time.perf_counter()
        self.session._fault("dispatch", (self._query_fp,))
        mask, cols = entry.fn(params, env_token[0])
        dispatch_s = time.perf_counter() - t0
        stats = {**entry.stats, "compiled": True, "async": True,
                 "dispatch_s": dispatch_s}
        result: QueryResult

        def materialize() -> MaskedTable:
            t1 = time.perf_counter()
            jax.block_until_ready(mask)
            sync_s = time.perf_counter() - t1
            result.stats["sync_s"] = sync_s
            result.elapsed_s = dispatch_s + sync_s
            table = Table(
                {n: Column(data, valid, entry.out_dicts.get(n))
                 for n, (data, valid) in cols.items()}
            )
            return MaskedTable(table, mask)

        result = QueryResult(None, entry.plan, dispatch_s, stats,
                             policy=self.policy,
                             cache_hit=exec_hit and plan_hit,
                             materialize=materialize)
        ar = AsyncResult(result, marker=mask, session=self.session)
        self.session._inflight.append(ar)
        self.session.async_stats["inflight_peak"] = max(
            self.session.async_stats["inflight_peak"],
            len(self.session._inflight),
        )
        return ar

    def _execute_compiled(self, params) -> QueryResult:
        env_token = self.session._env_token()
        entry, exec_hit, plan_hit = self.session._executable(
            self.node, self._query_fp, self.policy, params, env_token
        )
        t0 = time.perf_counter()
        self.session._fault("dispatch", (self._query_fp,))
        mask, cols = entry.fn(params, env_token[0])
        self.session._fault("sync", (self._query_fp,))
        jax.block_until_ready(mask)
        elapsed = time.perf_counter() - t0
        router = self.session.cost_router
        if router is not None:
            router.observe_serial(self._query_fp, self.policy, elapsed)
        table = Table(
            {n: Column(data, valid, entry.out_dicts.get(n))
             for n, (data, valid) in cols.items()}
        )
        masked = MaskedTable(table, mask)
        stats = {**entry.stats, "compiled": True}
        return QueryResult(masked, entry.plan, elapsed, stats,
                           policy=self.policy,
                           cache_hit=exec_hit and plan_hit)

    def _execute_eager(self, params) -> QueryResult:
        plan, plan_hit = self.session._cached_plan(
            self.node, self._query_fp, self.policy
        )
        warm = plan_hit and self._executed_plan == _stamp(plan)
        self._executed_plan = _stamp(plan)
        interp = self._eager_interp()
        executor = Executor(
            self.session.catalog,
            udf_column_evaluator=interp.eval_udf_call,
            use_pallas_agg=self.policy.pallas_agg,
        )
        pvals = {n: _param_value(v) for n, v in (params or {}).items()}
        before = dict(interp.stats)
        t0 = time.perf_counter()
        self.session._fault("interp", (self._query_fp,))
        masked = executor.execute(plan, params=pvals)
        jax.block_until_ready(masked.mask)
        elapsed = time.perf_counter() - t0
        # interpreter stats are cumulative over the statement's lifetime;
        # report this execution's delta
        delta = {k: interp.stats[k] - before.get(k, 0) for k in interp.stats}
        stats = {**executor.stats, **delta}
        return QueryResult(masked, plan, elapsed, stats,
                           policy=self.policy, cache_hit=warm)
