"""Figure 9: TPC-H queries rewritten with scalar UDFs (paper §8.2.4/§11).

For each query: (a) original (no UDFs), (b) rewritten with UDFs, froid OFF
(natively-compiled iterative — the *faster* baseline), (c) froid ON.
Correctness cross-check: (a) == (c) within float tolerance.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_run
from benchmarks.tpch_udfs import QUERIES, register_udfs
from repro.core import FROID, HEKATON, INTERPRETED, Session
from repro.data.tpch import generate_tpch

SF = 0.02  # 120k lineitems (CPU-scale)


def _results_match(db, qa, qb) -> bool:
    ra = db.execute(qa, FROID).table
    rb = db.execute(qb, FROID).table
    try:
        for name in ra.names():
            if name not in rb.columns:
                continue
            a = np.asarray(ra.columns[name].data, np.float64)
            b = np.asarray(rb.columns[name].data, np.float64)
            if a.shape != b.shape or not np.allclose(a, b, rtol=2e-3, atol=1e-2):
                return False
        return True
    except Exception:
        return False


def run(quick: bool = False, sf: float = SF):
    db = Session()
    generate_tpch(db, sf=sf)
    register_udfs(db)
    names = list(QUERIES)[:3] if quick else list(QUERIES)
    for name in names:
        q_udf, q_orig = QUERIES[name]
        qu, qo = q_udf(), q_orig()

        fn_orig = db.prepare(qo, FROID)
        t_orig = time_run(fn_orig)
        emit(f"fig9/{name}/original", t_orig * 1e6, "")

        fn_on = db.prepare(qu, FROID)
        t_on = time_run(fn_on)
        ok = _results_match(db, qu, qo)
        emit(f"fig9/{name}/udf_froid_on", t_on * 1e6,
             f"vs_orig={t_on/t_orig:.2f}x match={ok}")

        fn_off = db.prepare(qu, HEKATON)
        t_off = time_run(fn_off, warmup=1, iters=1)
        emit(f"fig9/{name}/udf_froid_off_native", t_off * 1e6,
             f"slowdown_vs_on={t_off/t_on:.1f}x")

        # interpreted mode (the paper's actual baseline): measure per-row
        # cost on a subset, extrapolate to the full cardinality
        sub = _subset_db(db, rows=300)
        register_udfs(sub)
        r = sub.execute(qu, INTERPRETED)
        n_sub = sub.catalog["lineitem"].num_rows
        n_full = db.catalog["lineitem"].num_rows
        t_interp = r.elapsed_s * n_full / n_sub
        emit(f"fig9/{name}/udf_froid_off_interpreted", t_interp * 1e6,
             f"extrapolated_from_{n_sub}_rows slowdown_vs_on={t_interp/t_on:.0f}x")


def _subset_db(db: Session, rows: int) -> Session:
    """Copy of the db with lineitem truncated (for interpreted-mode cost)."""
    import jax.numpy as jnp

    from repro.tables.table import Column, Table

    sub = Session()
    for name, t in db.catalog.items():
        if name == "lineitem":
            cols = {
                n: Column(c.data[:rows], None if c.valid is None else c.valid[:rows],
                          c.dictionary)
                for n, c in t.columns.items()
            }
            sub.catalog[name] = Table(cols)
        else:
            sub.catalog[name] = t
    return sub


if __name__ == "__main__":
    run()
