"""Cursor/WHILE loop analysis and Aggify-style rewriting.

The pipeline stage between the imperative IR and the relational algebra:
``analysis.classify`` issues a :class:`~repro.loops.analysis.LoopVerdict`
for every loop statement, and ``rewrite.compile_loop`` turns rewritable
cursor loops into a single :class:`repro.core.relalg.LoopScan` operator
over the cursor's defining query.  Non-rewritable loops keep an explicit
verdict and fall back to the per-row interpreter (the correctness
oracle's reference semantics).
"""
from repro.loops.analysis import LoopVerdict, classify, reduce_info
from repro.loops.rewrite import compile_loop

__all__ = ["LoopVerdict", "classify", "reduce_info", "compile_loop"]
