"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155.  [hf:ibm-granite/granite-3.0-2b-base]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-3-2b",
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=49155,
        head_dim=64,
        super_block=(LayerSpec(mixer="attn", mlp="dense"),),
        n_repeats=40,
        tie_embeddings=True,
        max_seq_len=131_072,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        config(), d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
        head_dim=16, n_repeats=2, max_seq_len=128,
    )
