"""TPC-H-style data generator (scaled-down, schema-faithful for the
columns the paper's §11 UDF queries touch).  Dates are day numbers since
1970-01-01; strings are dictionary-encoded by Table.from_arrays.
"""
from __future__ import annotations

import numpy as np

from repro.core import Database
from repro.tables.table import days_from_civil


def _day(y, m, d):
    import jax.numpy as jnp

    return int(np.asarray(days_from_civil(jnp.asarray(y), jnp.asarray(m),
                                          jnp.asarray(d))))


SEGMENTS = ["AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD"]
PRIORITIES = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"]
SHIPMODES = ["REG AIR", "AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB"]
SHIPINSTR = ["DELIVER IN PERSON", "COLLECT COD", "NONE", "TAKE BACK RETURN"]
CONTAINERS = [
    "SM CASE", "SM BOX", "SM PACK", "SM PKG",
    "MED BAG", "MED BOX", "MED PKG", "MED PACK",
    "LG CASE", "LG BOX", "LG PACK", "LG PKG",
    "JUMBO BAG", "WRAP CASE",
]
BRANDS = [f"Brand#{i}{j}" for i in range(1, 6) for j in range(1, 6)]
PTYPES = [
    "PROMO BURNISHED COPPER", "PROMO PLATED STEEL", "PROMO ANODIZED TIN",
    "STANDARD BRUSHED NICKEL", "ECONOMY POLISHED BRASS", "MEDIUM PLATED TIN",
    "LARGE BURNISHED STEEL", "SMALL ANODIZED COPPER",
]
PNAMES = [
    "lemon green tomato", "forest khaki blue", "green misty rose",
    "navy ivory slate", "dark olive green", "plum beige thistle",
    "red metallic snow", "spring green powder",
]
NATIONS = [
    ("ALGERIA", 0), ("ARGENTINA", 1), ("BRAZIL", 1), ("CANADA", 1),
    ("EGYPT", 4), ("ETHIOPIA", 0), ("FRANCE", 3), ("GERMANY", 3),
    ("INDIA", 2), ("INDONESIA", 2), ("IRAN", 4), ("IRAQ", 4),
    ("JAPAN", 2), ("JORDAN", 4), ("KENYA", 0), ("MOROCCO", 0),
    ("MOZAMBIQUE", 0), ("PERU", 1), ("CHINA", 2), ("ROMANIA", 3),
    ("SAUDI ARABIA", 4), ("VIETNAM", 2), ("RUSSIA", 3),
    ("UNITED KINGDOM", 3), ("UNITED STATES", 1),
]
REGIONS = ["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"]
CNTRYCODES = ["13", "31", "23", "29", "30", "18", "17", "15", "25", "11"]


def generate_tpch(db: Database, sf: float = 0.01, seed: int = 0) -> Database:
    """Populate ``db`` with TPC-H tables at scale factor ``sf``
    (sf=1.0 == 6M lineitems; default 0.01 == 60k)."""
    rng = np.random.default_rng(seed)
    n_orders = max(int(1_500_000 * sf), 100)
    n_line = max(int(6_000_000 * sf), 400)
    n_cust = max(int(150_000 * sf), 50)
    n_part = max(int(200_000 * sf), 50)
    n_supp = max(int(10_000 * sf), 20)
    n_psupp = n_part * 4

    d0 = _day(1992, 1, 1)
    d1 = _day(1998, 8, 2)

    db.create_table(
        "region",
        r_regionkey=np.arange(len(REGIONS)),
        r_name=np.array(REGIONS),
    )
    nk = np.arange(len(NATIONS))
    db.create_table(
        "nation",
        n_nationkey=nk,
        n_name=np.array([n for n, _ in NATIONS]),
        n_regionkey=np.array([r for _, r in NATIONS]),
    )
    db.create_table(
        "supplier",
        s_suppkey=np.arange(n_supp),
        s_nationkey=rng.integers(0, len(NATIONS), n_supp),
    )
    db.create_table(
        "customer",
        c_custkey=np.arange(n_cust),
        c_nationkey=rng.integers(0, len(NATIONS), n_cust),
        c_acctbal=np.round(rng.uniform(-999, 9999, n_cust), 2).astype(np.float32),
        c_mktsegment=np.array(SEGMENTS)[rng.integers(0, len(SEGMENTS), n_cust)],
        c_phone_cc=np.array(CNTRYCODES)[rng.integers(0, len(CNTRYCODES), n_cust)],
        c_name=np.array([f"Customer#{i:09d}" for i in range(n_cust)]),
    )
    db.create_table(
        "part",
        p_partkey=np.arange(n_part),
        p_brand=np.array(BRANDS)[rng.integers(0, len(BRANDS), n_part)],
        p_type=np.array(PTYPES)[rng.integers(0, len(PTYPES), n_part)],
        p_container=np.array(CONTAINERS)[rng.integers(0, len(CONTAINERS), n_part)],
        p_size=rng.integers(1, 51, n_part),
        p_name=np.array(PNAMES)[rng.integers(0, len(PNAMES), n_part)],
    )
    db.create_table(
        "partsupp",
        ps_partkey=np.repeat(np.arange(n_part), 4),
        ps_suppkey=rng.integers(0, n_supp, n_psupp),
        ps_supplycost=np.round(rng.uniform(1, 1000, n_psupp), 2).astype(np.float32),
        ps_availqty=rng.integers(1, 10_000, n_psupp),
    )
    odate = rng.integers(d0, d1 - 151, n_orders)
    db.create_table(
        "orders",
        o_orderkey=np.arange(n_orders),
        o_custkey=rng.integers(0, n_cust, n_orders),
        o_orderdate=odate.astype(np.int32),
        o_orderpriority=np.array(PRIORITIES)[
            rng.integers(0, len(PRIORITIES), n_orders)
        ],
        o_shippriority=np.zeros(n_orders, np.int32),
        o_totalprice=np.round(rng.uniform(900, 500_000, n_orders), 2).astype(
            np.float32
        ),
    )
    l_order = rng.integers(0, n_orders, n_line)
    l_ship = odate[l_order] + rng.integers(1, 122, n_line)
    l_commit = odate[l_order] + rng.integers(30, 91, n_line)
    l_receipt = l_ship + rng.integers(1, 31, n_line)
    db.create_table(
        "lineitem",
        l_orderkey=l_order,
        l_partkey=rng.integers(0, n_part, n_line),
        l_suppkey=rng.integers(0, n_supp, n_line),
        l_quantity=rng.integers(1, 51, n_line),
        l_extendedprice=np.round(rng.uniform(900, 100_000, n_line), 2).astype(
            np.float32
        ),
        l_discount=np.round(rng.uniform(0.0, 0.1, n_line), 2).astype(np.float32),
        l_tax=np.round(rng.uniform(0.0, 0.08, n_line), 2).astype(np.float32),
        l_returnflag=np.array(["R", "A", "N"])[rng.integers(0, 3, n_line)],
        l_linestatus=np.array(["O", "F"])[rng.integers(0, 2, n_line)],
        l_shipdate=l_ship.astype(np.int32),
        l_commitdate=l_commit.astype(np.int32),
        l_receiptdate=l_receipt.astype(np.int32),
        l_shipinstruct=np.array(SHIPINSTR)[rng.integers(0, len(SHIPINSTR), n_line)],
        l_shipmode=np.array(SHIPMODES)[rng.integers(0, len(SHIPMODES), n_line)],
    )
    return db


def tpch_dates():
    """Commonly used literal dates as day numbers."""
    return {
        "1994-01-01": _day(1994, 1, 1),
        "1995-01-01": _day(1995, 1, 1),
        "1995-03-15": _day(1995, 3, 15),
        "1995-09-01": _day(1995, 9, 1),
        "1996-12-31": _day(1996, 12, 31),
        "1993-10-01": _day(1993, 10, 1),
        "1998-12-01": _day(1998, 12, 1),
    }
