"""Typed resilience errors + the deterministic fault-injection harness.

Froid's production story (PAPER.md §6) hinges on safe fallback: an
unsupported construct reverts to interpreted execution instead of failing
the query.  Our engine has a four-deep stack of execution alternatives
(fused wave → batched ``execute_many`` → serial compiled ``execute`` →
per-row interpretation), and the degradation ladder (``ladder.py``) walks
it on failure.  This module supplies the two things the ladder's contract
needs to be *testable*:

* **Typed errors** — every error the resilience layer itself originates is
  a :class:`ResilienceError` subclass, so the chaos oracle can distinguish
  "the engine degraded explicitly" from "the engine corrupted or lost a
  ticket".
* **:class:`FaultInjector`** — a hook installed into the ``Session``
  executor seams (``session.fault_injector = fi`` /
  ``fi.install(session)``) that raises :class:`InjectedFault` at named
  sites (``compile`` / ``dispatch`` / ``sync`` / ``interp``), optionally
  scoped to one statement fingerprint, on an explicit occurrence schedule
  (:class:`FaultSpec`) or a seeded deterministic pseudo-random schedule
  (:meth:`FaultInjector.seeded`).  The injector never mutates engine
  state — it only raises — so any fault schedule is replayable and the
  fault-free run is byte-identical to an uninstrumented session.

Sites (each ``check`` carries the tuple of statement fingerprints the
operation serves, so specs can target one statement of a fused wave):

* ``compile``  — executable construction on a cache miss (trace + jit),
  for the unbatched, batched, sharded and fused tiers alike.
* ``dispatch`` — issuing the device call of a built executable.
* ``sync``     — blocking on a dispatched call's results.
* ``interp``   — eager per-row interpreted execution (the ladder's last
  tier; injecting here proves tickets surface *typed* errors when even
  the interpreter fails).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

#: the sites Session seams report, in pipeline order
SITES = ("compile", "dispatch", "sync", "interp")


class ResilienceError(RuntimeError):
    """Base of every error the resilience layer originates.  The chaos
    oracle's contract: under any injected fault schedule a ticket either
    carries the fault-free answer or raises one of these — never wrong
    data, never a hang."""


class InjectedFault(ResilienceError):
    """The fault-injection harness fired at a seam."""

    def __init__(self, site: str, statements: tuple, occurrence: int,
                 origin: str = "spec"):
        self.site = site
        self.statements = statements
        self.occurrence = occurrence
        self.origin = origin
        super().__init__(
            f"injected {site} fault (occurrence {occurrence}, {origin})"
        )


class DeadlineExceeded(ResilienceError):
    """A ticket's deadline passed before its work (or retry) started; it
    was shed instead of drained."""

    def __init__(self, deadline: float, now: float):
        self.deadline = deadline
        self.now = now
        super().__init__(
            f"ticket deadline exceeded ({now - deadline:.4f}s past deadline)"
        )


class WaveResultMismatch(ResilienceError):
    """A drain returned a different result count than the wave submitted —
    a protocol violation that fails the wave with a typed error (and lets
    the ladder retry a tier down) instead of leaking ``StopIteration`` or
    silently dropping results."""

    def __init__(self, expected: int, got: int, where: str):
        self.expected = expected
        self.got = got
        super().__init__(
            f"{where} returned {got} results for {expected} calls"
        )


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault: fail matching seam events.

    ``site``  — one of :data:`SITES` or ``"*"`` (any site).
    ``stmt``  — a statement fingerprint (``PreparedStatement._query_fp``);
    ``None`` matches any statement.  A fused-wave event matches when the
    fingerprint is *any* member of the wave.
    ``after`` — skip this many matching events before firing.
    ``times`` — fire on this many matching events, then go quiet
    (``None`` = fire forever: the persistent-failure shape circuit
    breakers exist for).
    """

    site: str = "*"
    stmt: Any = None
    after: int = 0
    times: int | None = 1
    # runtime counters (not part of the schedule identity)
    seen: int = dataclasses.field(default=0, compare=False)
    fired: int = dataclasses.field(default=0, compare=False)

    def matches(self, site: str, statements: tuple) -> bool:
        if self.site != "*" and self.site != site:
            return False
        if self.stmt is not None and self.stmt not in statements:
            return False
        return True

    def should_fire(self) -> bool:
        """Consume one matching event; True when this event faults."""
        self.seen += 1
        if self.seen <= self.after:
            return False
        if self.times is not None and self.fired >= self.times:
            return False
        self.fired += 1
        return True


def _seeded_fraction(seed: int, site: str, index: int) -> float:
    """Deterministic uniform-ish fraction for event ``index`` at ``site``:
    same seed → same schedule, independent of wall clock or dict order."""
    h = hashlib.sha1(f"{seed}:{site}:{index}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


class FaultInjector:
    """Deterministic failure source for the Session executor seams.

    Explicit schedules::

        fi = FaultInjector([FaultSpec(site="dispatch", times=1)])
        fi.install(session)

    Seeded pseudo-random schedules (the chaos fuzzing surface)::

        fi = FaultInjector.seeded(seed=7, rate=0.3).install(session)

    ``events`` counts seam checks per site; ``injected`` logs every fired
    fault as ``(site, statements, occurrence)`` — the observability the
    chaos tests assert on.  ``check`` raises :class:`InjectedFault` and
    never mutates engine state, so schedules replay exactly.
    """

    def __init__(self, specs=()):
        self.specs: list[FaultSpec] = list(specs)
        self.events: dict[str, int] = {}
        self.injected: list[tuple] = []
        self._seed: int | None = None
        self._rate: float = 0.0
        self._seeded_sites: tuple = ()
        self._max_faults: int | None = None

    @classmethod
    def seeded(cls, seed: int, rate: float,
               sites: tuple = ("compile", "dispatch", "sync"),
               max_faults: int | None = None) -> "FaultInjector":
        """A deterministic pseudo-random schedule: each seam event at one
        of ``sites`` fails with probability ``rate``, decided by a hash of
        ``(seed, site, per-site event index)`` — no RNG state, so the
        schedule depends only on the event sequence.  ``max_faults``
        bounds total fired faults (so a high rate cannot starve every
        ladder tier forever)."""
        fi = cls()
        fi._seed = int(seed)
        fi._rate = float(rate)
        fi._seeded_sites = tuple(sites)
        fi._max_faults = max_faults
        return fi

    def install(self, session) -> "FaultInjector":
        session.fault_injector = self
        return self

    @property
    def fired(self) -> int:
        return len(self.injected)

    def check(self, site: str, statements: tuple = ()) -> None:
        """Seam hook: raise :class:`InjectedFault` when the schedule says
        this event fails; otherwise return (and count the event)."""
        n = self.events.get(site, 0)
        self.events[site] = n + 1
        for spec in self.specs:
            if spec.matches(site, statements) and spec.should_fire():
                self.injected.append((site, statements, n))
                raise InjectedFault(site, statements, n, origin="spec")
        if (self._seed is not None and site in self._seeded_sites
                and (self._max_faults is None
                     or self.fired < self._max_faults)
                and _seeded_fraction(self._seed, site, n) < self._rate):
            self.injected.append((site, statements, n))
            raise InjectedFault(site, statements, n, origin="seeded")


__all__ = [
    "SITES",
    "ResilienceError",
    "InjectedFault",
    "DeadlineExceeded",
    "WaveResultMismatch",
    "FaultSpec",
    "FaultInjector",
]
